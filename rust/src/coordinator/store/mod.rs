//! The tiered value store: hot / warm / cold backends behind one facade.
//!
//! The paper keeps task data in memory and crosses the serialization
//! boundary only when a value actually leaves a node; Eddelbuettel's
//! parallel-R review (PAPERS.md) identifies that boundary — R-object
//! serialization — as the dominant fixed cost of every R parallel
//! backend. This module organizes the data plane around it. Every `dXvY`
//! version lives in (at most) three representations, one per tier:
//!
//! | tier | backend | representation | codec cost to reach |
//! |------|---------|----------------|---------------------|
//! | **hot** | [`hot::DataStore`] | decoded `Arc<RValue>` | none (zero-copy) |
//! | **warm** | [`warm::WarmStore`] | encoded `Arc<[u8]>` blob | one decode |
//! | **cold** | [`cold::ColdStore`] + workdir | spill file | one decode + one file read |
//!
//! Demotion flows **hot → warm → cold** (`demote_victims`): memory
//! pressure encodes the victim into the warm tier (one codec call, no
//! disk), and only warm-budget pressure flushes blobs to spill files —
//! written verbatim, the codec never runs twice. Promotion climbs back
//! without touching a lower tier than needed: a warm hit decodes in
//! memory, only a cold miss reads a file. The transfer plane ships warm
//! blobs directly (`stage_blob`): an N-node fan-out of a memory-resident
//! version costs exactly one encode and zero file I/O, where the pre-tier
//! runtime paid one encode plus N file write/read round-trips
//! (`stage_replica → ensure_file → codec.read_file`). The
//! `cold::ensure_file` path survives only as the cold-tier fallback.
//!
//! Each backend implements [`ValueStore`]; the [`TieredStore`] facade owns
//! one of each plus the cross-tier counters (`encode_count` is the
//! headline: the fan-out acceptance test pins it to 1). The version GC
//! drains **all three tiers** when it collects a version — see
//! `runtime::collect_version`, which iterates the resident tiers and
//! deletes the published file, loudly, only when one actually exists
//! (per-tier residency is tracked, so a missing file is a reported leak,
//! not a swallowed error).
//!
//! Configuration: `--memory-budget` sizes hot, `--warm-budget` sizes warm
//! (0 = off: pre-tier behavior byte for byte), `--store tiered|hot|file`
//! picks a preset for A/B runs. With the memory plane off the warm tier is
//! forced off too — a serialized-bytes cache would shadow the
//! seed-identical file plane the codec tests pin.

pub mod cold;
pub mod hot;
pub mod warm;

pub use cold::ColdStore;
pub use hot::{DataStore, SpillPolicy, SpillVictim};
pub use warm::{WarmStore, WarmVictim};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Context;

use crate::coordinator::registry::{DataKey, VersionTable};
use crate::coordinator::runtime::Shared;

/// The three storage tiers, cheapest-to-reach first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Decoded values, zero-copy consumption.
    Hot,
    /// Encoded blobs, one decode away.
    Warm,
    /// Spill files, a file read plus a decode away.
    Cold,
}

impl Tier {
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Hot => "hot",
            Tier::Warm => "warm",
            Tier::Cold => "cold",
        }
    }
}

/// One backend tier of the tiered value store. The facade
/// ([`TieredStore`]) owns one implementation per tier; the version GC and
/// the stats surface iterate [`TieredStore::tiers`], so a new backend can
/// be forgotten by neither.
pub trait ValueStore: Send + Sync {
    /// Which tier this backend implements.
    fn tier(&self) -> Tier;
    /// Is the tier active under the current configuration?
    fn enabled(&self) -> bool;
    /// Payload bytes currently resident in this tier.
    fn resident_bytes(&self) -> u64;
    /// Number of versions with an entry in this tier.
    fn entry_count(&self) -> usize;
    /// Does this tier hold `key`?
    fn contains(&self, key: DataKey) -> bool;
    /// Drop `key`'s entry from this tier (version GC / explicit removal).
    /// Returns the payload bytes freed, `None` when the tier held nothing.
    fn discard(&self, key: DataKey) -> Option<u64>;
}

/// The facade over the three tiers. The runtime holds exactly one; hot-
/// and warm-tier operations go through the [`TieredStore::hot`] /
/// [`TieredStore::warm`] accessors (tier residency stays explicit at the
/// call sites), cross-tier flows through the free functions of this
/// module, and the cross-tier counters live here.
pub struct TieredStore {
    hot: DataStore,
    warm: WarmStore,
    cold: ColdStore,
    /// Codec `encode` invocations by the data plane (demotions, transfer
    /// fills, spill-file writes). The fan-out acceptance test pins this to
    /// exactly 1 for an N-node transfer of a memory-resident version.
    encodes: AtomicU64,
}

impl TieredStore {
    /// Build the tier stack. A `memory_budget` of 0 (file plane) forces
    /// the warm tier off as well: with every parameter on disk, a
    /// serialized-bytes cache would shadow the seed-identical behavior the
    /// codec tests pin.
    pub fn new(
        memory_budget: u64,
        policy: SpillPolicy,
        warm_budget: u64,
        table: Arc<VersionTable>,
    ) -> TieredStore {
        let warm_budget = if memory_budget == 0 { 0 } else { warm_budget };
        TieredStore {
            hot: DataStore::new(memory_budget, policy),
            warm: WarmStore::new(warm_budget),
            cold: ColdStore::new(table),
            encodes: AtomicU64::new(0),
        }
    }

    pub fn hot(&self) -> &DataStore {
        &self.hot
    }

    pub fn warm(&self) -> &WarmStore {
        &self.warm
    }

    pub fn cold(&self) -> &ColdStore {
        &self.cold
    }

    /// Every tier, hottest first — the iteration surface for the GC drain
    /// and the stats snapshot.
    pub fn tiers(&self) -> [&dyn ValueStore; 3] {
        [&self.hot, &self.warm, &self.cold]
    }

    /// Memory plane on? (Hot-tier budget > 0 — the facade-level switch the
    /// claim/publish paths branch on.)
    pub fn enabled(&self) -> bool {
        self.hot.enabled()
    }

    /// Count one codec `encode` run by the data plane.
    pub(crate) fn note_encode(&self) {
        self.encodes.fetch_add(1, Ordering::Relaxed);
    }

    /// Codec `encode` invocations by the data plane.
    pub fn encode_count(&self) -> u64 {
        self.encodes.load(Ordering::Relaxed)
    }

    /// GC: drop a collected version from the resident tiers (hot + warm),
    /// through the [`ValueStore`] trait so a future backend cannot be
    /// skipped. The cold file is handled by the caller: the collect path
    /// already took the version's file path out of the table (see
    /// `CollectAction`), so the cold tier's own `discard` would find
    /// nothing — deleting through it here would be dead weight, not a
    /// second delete.
    pub(crate) fn discard_resident(&self, key: DataKey) {
        for tier in self.tiers() {
            if tier.tier() != Tier::Cold {
                tier.discard(key);
            }
        }
    }
}

/// Demote hot-tier spill victims down the tier ladder: **hot → warm** when
/// the warm tier is on (one encode, no disk), **hot → cold** otherwise
/// (the pre-tier spill file, byte-identical). A victim whose bytes already
/// sit in a lower tier (`has_file`, or a live warm blob) drops for free.
/// Demotion failures never fail tasks: the value stays resident (over
/// budget) and the store keeps it evictable, which degrades memory use,
/// not results.
pub(crate) fn demote_victims(shared: &Shared, victims: Vec<SpillVictim>) {
    for v in victims {
        if v.has_file || shared.store.warm().contains(v.key) {
            // An up-to-date file or blob already holds the bytes (the
            // value was promoted from one, or spilled for a transfer):
            // eviction is free.
            shared.store.hot().finish_spill(v.key, false, 0);
            continue;
        }
        if shared.store.warm().enabled() {
            match shared.codec.encode(&v.value) {
                Ok(bytes) => {
                    shared.store.note_encode();
                    let nbytes = bytes.len() as u64;
                    let blob: Arc<[u8]> = bytes.into();
                    // Real serialized size sharpens every later byte
                    // estimate (transfer requests, cost/adaptive routing).
                    shared.table.update_bytes(v.key, nbytes);
                    let evicted = shared.store.warm().put(v.key, blob, false);
                    write_warm_victims(shared, evicted);
                    if shared.table.is_collected(v.key) {
                        // The GC collected the version mid-encode:
                        // whichever of the two removals runs last clears
                        // the blob.
                        shared.store.warm().remove(v.key);
                    }
                    shared.store.hot().finish_spill(v.key, true, nbytes);
                }
                Err(e) => {
                    eprintln!(
                        "[rcompss] warm demotion of {} failed ({e:#}); keeping it resident",
                        v.key
                    );
                    shared.store.hot().abort_spill(v.key);
                }
            }
            continue;
        }
        match cold::write_spill_file(shared, v.key, &v.value) {
            Ok((bytes, path)) => {
                if shared.table.mark_spilled(v.key, bytes, path.clone()) {
                    shared.store.hot().finish_spill(v.key, true, bytes);
                } else {
                    // The GC collected the version while we were encoding
                    // it: the file is an orphan — delete instead of
                    // publishing, and drop the (already removed) entry.
                    let _ = std::fs::remove_file(&path);
                    shared.store.hot().finish_spill(v.key, false, 0);
                }
            }
            Err(e) => {
                eprintln!("[rcompss] spill of {} failed ({e:#}); keeping it resident", v.key);
                shared.store.hot().abort_spill(v.key);
            }
        }
    }
}

/// Flush warm-tier eviction victims to the cold tier: the blob bytes go to
/// the spill file verbatim (the warm tier already paid the encode), the
/// path is published, and the two-phase eviction completes.
pub(crate) fn write_warm_victims(shared: &Shared, victims: Vec<WarmVictim>) {
    for v in victims {
        if v.has_file {
            shared.store.warm().finish_evict(v.key, false);
            continue;
        }
        match cold::publish_blob_file(shared, v.key, &v.blob) {
            Ok((bytes, path)) => {
                if shared.table.mark_spilled(v.key, bytes, path.clone()) {
                    shared.store.hot().note_file(v.key);
                    shared.store.warm().finish_evict(v.key, true);
                } else {
                    let _ = std::fs::remove_file(&path);
                    shared.store.warm().finish_evict(v.key, false);
                }
            }
            Err(e) => {
                eprintln!(
                    "[rcompss] warm eviction of {} failed ({e:#}); keeping the blob resident",
                    v.key
                );
                shared.store.warm().abort_evict(v.key);
            }
        }
    }
}

/// Get-or-build the serialized blob the transfer movers ship: a warm hit
/// reuses the cached encode; a miss encodes the hot value — or slurps an
/// existing spill file, one raw read for the whole fan-out — exactly once
/// per version (racing movers park on the fill). `Ok(None)` means the
/// warm tier is off or the bytes were transiently unreachable; the caller
/// falls back to [`cold::ensure_file`].
pub(crate) fn stage_blob(shared: &Shared, key: DataKey) -> anyhow::Result<Option<Arc<[u8]>>> {
    if !shared.store.warm().enabled() {
        return Ok(None);
    }
    let (blob, victims) = shared.store.warm().get_or_fill(key, || {
        if let Some(v) = shared.store.hot().get(key) {
            let bytes = shared.codec.encode(&v)?;
            shared.store.note_encode();
            // Real serialized size sharpens every later byte estimate
            // (transfer requests, cost/adaptive routing).
            shared.table.update_bytes(key, bytes.len() as u64);
            return Ok(Some((bytes.into(), false)));
        }
        if let Some(path) = shared.table.path_of(key) {
            // Cold-resident: one raw file read fills the blob (marked
            // `has_file`, so even an immediate eviction never rewrites the
            // very file it came from); the remaining N-1 fan-out transfers
            // hit warm.
            let bytes = std::fs::read(&path)
                .with_context(|| format!("read spill {}", path.display()))?;
            shared.store.cold().note_read();
            return Ok(Some((bytes.into(), true)));
        }
        Ok(None)
    })?;
    write_warm_victims(shared, victims);
    if blob.is_some() && shared.table.is_collected(key) {
        // A fill racing the GC: whichever removal runs last clears it.
        shared.store.warm().remove(key);
    }
    Ok(blob)
}
