//! The **warm tier**: a byte-budgeted cache of *serialized* value blobs.
//!
//! Eddelbuettel's review of parallel R (PAPERS.md) identifies R-object
//! serialization as the dominant fixed cost of every R parallel backend;
//! the RCOMPSs paper's answer is to cross the serialization boundary only
//! when a value actually leaves a node. The warm tier takes that one step
//! further: once a value *is* encoded — by memory pressure demoting it out
//! of the hot tier, or by the first cross-node transfer — the encoded
//! bytes are worth keeping. A [`WarmStore`] entry is an `Arc<[u8]>` blob
//! keyed by the `dXvY` [`DataKey`]:
//!
//! * **demotion** (hot → warm) parks the encoded bytes here instead of on
//!   disk, so a later reload is a pure in-memory decode — zero file I/O;
//! * **transfer staging** ships the blob directly: an N-node fan-out of a
//!   memory-resident version costs exactly **one** encode (the fill) and
//!   N−1 warm hits, where the file-backed path paid one encode plus N file
//!   write/read round-trips;
//! * **eviction** (warm → cold) writes the blob bytes verbatim to the
//!   spill file — the codec never runs again on the way down.
//!
//! Entries are filled lazily by the first encode ([`WarmStore::get_or_fill`]
//! runs the caller's encode exactly once per version; racing movers park on
//! the fill) and evicted LRU-first under the `--warm-budget` byte budget.
//! A budget of 0 disables the tier: every path degrades to the pre-tier
//! hot→file behavior, byte for byte.
//!
//! The two-phase eviction protocol mirrors the hot tier's: `put` marks
//! victims `evicting` (still readable), the caller publishes their file
//! path, and only [`WarmStore::finish_evict`] drops the blob — a reader
//! always finds the bytes in a tier or at a published path.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::registry::DataKey;
use crate::coordinator::store::{Tier, ValueStore};

/// A blob selected for eviction to the cold tier: still readable in the
/// warm store until the caller publishes its file and calls
/// [`WarmStore::finish_evict`].
pub struct WarmVictim {
    pub key: DataKey,
    pub blob: Arc<[u8]>,
    /// An up-to-date spill file already exists (the blob was slurped from
    /// one, or an earlier eviction published it): dropping the entry is
    /// free — no file write needed.
    pub has_file: bool,
}

struct Entry {
    blob: Arc<[u8]>,
    last_used: u64,
    /// Selected as an eviction victim; excluded from further selection and
    /// from the resident-byte total, but still served by `get`.
    evicting: bool,
    /// An up-to-date spill file for this version already exists on disk.
    has_file: bool,
}

#[derive(Default)]
struct Inner {
    map: HashMap<DataKey, Entry>,
    /// Bytes held by entries not currently being evicted.
    resident: u64,
    /// Versions whose first blob is being encoded by a caller of
    /// [`WarmStore::get_or_fill`]; racing callers park on `cv_fill` so a
    /// fan-out transfer encodes each version exactly once.
    filling: HashSet<DataKey>,
}

/// The warm serialized-bytes store. All methods take `&self`; a budget of
/// 0 makes every operation a cheap no-op (the tier is off).
pub struct WarmStore {
    budget: u64,
    tick: AtomicU64,
    inner: Mutex<Inner>,
    /// Fill waiters park here (see [`WarmStore::get_or_fill`]).
    cv_fill: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
    fills: AtomicU64,
    evictions: AtomicU64,
}

impl WarmStore {
    pub fn new(budget: u64) -> WarmStore {
        WarmStore {
            budget,
            tick: AtomicU64::new(0),
            inner: Mutex::new(Inner::default()),
            cv_fill: Condvar::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            fills: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Is the warm tier active?
    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Zero-copy blob lookup; bumps recency and the hit/miss counters.
    pub fn get(&self, key: DataKey) -> Option<Arc<[u8]>> {
        if !self.enabled() {
            return None;
        }
        let now = self.tick.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        match inner.map.get_mut(&key) {
            Some(e) => {
                e.last_used = now;
                let b = Arc::clone(&e.blob);
                drop(inner);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(b)
            }
            None => {
                drop(inner);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Peek without touching recency or counters (tests, demotion checks).
    pub fn contains(&self, key: DataKey) -> bool {
        self.enabled() && self.inner.lock().unwrap().map.contains_key(&key)
    }

    /// Insert an encoded blob (a hot-tier demotion) and return any victims
    /// that must be flushed to the cold tier to stay within budget. The
    /// caller must write each victim's file, publish its path, then call
    /// [`WarmStore::finish_evict`].
    #[must_use = "victims must be flushed to cold and finish_evict()ed"]
    pub fn put(&self, key: DataKey, blob: Arc<[u8]>, has_file: bool) -> Vec<WarmVictim> {
        if !self.enabled() {
            return Vec::new();
        }
        let now = self.tick.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        self.insert_locked(&mut inner, key, blob, has_file, now)
    }

    /// Shared insert + victim selection (lock held).
    fn insert_locked(
        &self,
        inner: &mut Inner,
        key: DataKey,
        blob: Arc<[u8]>,
        has_file: bool,
        now: u64,
    ) -> Vec<WarmVictim> {
        let bytes = blob.len() as u64;
        let entry = Entry {
            blob,
            last_used: now,
            evicting: false,
            has_file,
        };
        if let Some(old) = inner.map.insert(key, entry) {
            // Re-insert of the same version: keep the byte accounting
            // consistent (mirrors the hot tier).
            if !old.evicting {
                inner.resident = inner.resident.saturating_sub(old.blob.len() as u64);
            }
        }
        inner.resident += bytes;
        self.fills.fetch_add(1, Ordering::Relaxed);

        let mut victims = Vec::new();
        while inner.resident > self.budget {
            let pick = inner
                .map
                .iter()
                .filter(|(_, e)| !e.evicting)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            let Some(k) = pick else { break };
            let e = inner.map.get_mut(&k).expect("victim entry");
            e.evicting = true;
            inner.resident = inner.resident.saturating_sub(e.blob.len() as u64);
            victims.push(WarmVictim {
                key: k,
                blob: Arc::clone(&e.blob),
                has_file: e.has_file,
            });
        }
        victims
    }

    /// Look the blob up, or build it exactly once: when `key` has no entry
    /// and nobody is filling it, `make` runs (outside the store lock) and
    /// its result is inserted — the returned `has_file` flag marks blobs
    /// slurped from an existing spill file, whose eviction is free (an
    /// oversized fill must not rewrite the very file it was read from).
    /// Racing callers for the same key park until the fill completes and
    /// then take the hit path. `make` returning `Ok(None)` means the bytes
    /// are not reachable without the cold tier — nothing is inserted and
    /// every parked caller retries for itself.
    ///
    /// Returns the blob (if any) plus eviction victims the caller must
    /// flush to the cold tier (see [`WarmStore::put`]).
    pub fn get_or_fill(
        &self,
        key: DataKey,
        make: impl FnOnce() -> anyhow::Result<Option<(Arc<[u8]>, bool)>>,
    ) -> anyhow::Result<(Option<Arc<[u8]>>, Vec<WarmVictim>)> {
        if !self.enabled() {
            return Ok((None, Vec::new()));
        }
        {
            let mut inner = self.inner.lock().unwrap();
            loop {
                if inner.map.contains_key(&key) {
                    let now = self.tick.fetch_add(1, Ordering::Relaxed);
                    let e = inner.map.get_mut(&key).expect("entry just seen");
                    e.last_used = now;
                    let b = Arc::clone(&e.blob);
                    drop(inner);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((Some(b), Vec::new()));
                }
                if !inner.filling.contains(&key) {
                    break;
                }
                inner = self.cv_fill.wait(inner).unwrap();
            }
            inner.filling.insert(key);
        }
        // The encode runs outside the lock; racing callers of this key are
        // parked above until `filling` clears.
        let made = make();
        let mut inner = self.inner.lock().unwrap();
        inner.filling.remove(&key);
        self.cv_fill.notify_all();
        match made {
            Ok(Some((blob, has_file))) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                let now = self.tick.fetch_add(1, Ordering::Relaxed);
                let victims =
                    self.insert_locked(&mut inner, key, Arc::clone(&blob), has_file, now);
                Ok((Some(blob), victims))
            }
            Ok(None) => Ok((None, Vec::new())),
            Err(e) => Err(e),
        }
    }

    /// Drop an evicted blob once its file path is published. Counts the
    /// eviction (unless the file already existed, i.e. a free drop). If a
    /// concurrent insert replaced the entry with a fresh (non-evicting)
    /// blob in the meantime, that entry is left in place — it is
    /// separately accounted and still live.
    pub fn finish_evict(&self, key: DataKey, wrote_file: bool) {
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.map.get(&key).map(|e| e.evicting).unwrap_or(false) {
                inner.map.remove(&key);
            }
        }
        if wrote_file {
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Undo a victim selection after a failed cold write, so the blob
    /// stays reachable and evictable.
    pub fn abort_evict(&self, key: DataKey) {
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        if let Some(e) = inner.map.get_mut(&key) {
            if e.evicting {
                e.evicting = false;
                inner.resident += e.blob.len() as u64;
            }
        }
    }

    /// Mark that an up-to-date spill file now exists for a cached blob
    /// (publish-for-sync-fallback keeps the blob resident).
    pub fn note_file(&self, key: DataKey) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner.map.get_mut(&key) {
            e.has_file = true;
        }
    }

    /// Drop a version the GC reclaimed. Returns the blob bytes freed. An
    /// entry mid-eviction is removed too; its in-flight cold write
    /// finishes harmlessly against a missing entry.
    pub fn remove(&self, key: DataKey) -> Option<u64> {
        if !self.enabled() {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        match inner.map.remove(&key) {
            Some(e) => {
                let bytes = e.blob.len() as u64;
                if !e.evicting {
                    inner.resident = inner.resident.saturating_sub(bytes);
                }
                Some(bytes)
            }
            None => None,
        }
    }

    pub fn resident_bytes(&self) -> u64 {
        self.inner.lock().unwrap().resident
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hit_count(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn miss_count(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries ever created (pressure demotions + lazy transfer fills).
    pub fn fill_count(&self) -> u64 {
        self.fills.load(Ordering::Relaxed)
    }

    /// Blobs flushed to cold spill files by warm-budget pressure.
    pub fn eviction_count(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

impl ValueStore for WarmStore {
    fn tier(&self) -> Tier {
        Tier::Warm
    }

    fn enabled(&self) -> bool {
        WarmStore::enabled(self)
    }

    fn resident_bytes(&self) -> u64 {
        WarmStore::resident_bytes(self)
    }

    fn entry_count(&self) -> usize {
        self.len()
    }

    fn contains(&self, key: DataKey) -> bool {
        WarmStore::contains(self, key)
    }

    fn discard(&self, key: DataKey) -> Option<u64> {
        self.remove(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::DataId;
    use std::sync::atomic::AtomicUsize;

    fn key(d: u64) -> DataKey {
        DataKey {
            data: DataId(d),
            version: 1,
        }
    }

    fn blob(n: usize) -> Arc<[u8]> {
        vec![7u8; n].into()
    }

    #[test]
    fn disabled_store_is_inert() {
        let s = WarmStore::new(0);
        assert!(!s.enabled());
        assert!(s.put(key(1), blob(8), false).is_empty());
        assert!(s.get(key(1)).is_none());
        assert_eq!(s.len(), 0);
        assert_eq!(s.hit_count() + s.miss_count(), 0);
        // get_or_fill must not run the encode for a disabled tier.
        let (b, v) = s.get_or_fill(key(1), || panic!("encode on disabled tier")).unwrap();
        assert!(b.is_none() && v.is_empty());
    }

    #[test]
    fn put_get_returns_same_allocation() {
        let s = WarmStore::new(1 << 20);
        let b = blob(16);
        assert!(s.put(key(1), Arc::clone(&b), false).is_empty());
        let got = s.get(key(1)).unwrap();
        assert!(Arc::ptr_eq(&b, &got), "get must return the same blob");
        assert_eq!(s.hit_count(), 1);
        assert!(s.get(key(9)).is_none());
        assert_eq!(s.miss_count(), 1);
        assert_eq!(s.resident_bytes(), 16);
    }

    #[test]
    fn lru_evicts_the_oldest_untouched_blob() {
        let s = WarmStore::new(40);
        assert!(s.put(key(1), blob(16), false).is_empty());
        assert!(s.put(key(2), blob(16), false).is_empty());
        // Touch 1 so 2 becomes the LRU victim.
        s.get(key(1)).unwrap();
        let victims = s.put(key(3), blob(16), false);
        assert_eq!(victims.len(), 1);
        assert_eq!(victims[0].key, key(2));
        // Two-phase: the victim stays readable until finish_evict.
        assert!(s.get(key(2)).is_some());
        s.finish_evict(key(2), true);
        assert!(s.get(key(2)).is_none());
        assert_eq!(s.eviction_count(), 1);
        assert!(s.resident_bytes() <= 40);
    }

    #[test]
    fn abort_evict_restores_the_blob() {
        let s = WarmStore::new(10);
        let victims = s.put(key(1), blob(32), false);
        assert_eq!(victims.len(), 1, "oversized blob evicts itself");
        s.abort_evict(key(1));
        assert_eq!(s.resident_bytes(), 32);
        // Candidate again on the next overflow.
        let victims = s.put(key(2), blob(4), false);
        assert!(victims.iter().any(|v| v.key == key(1)));
        for v in victims {
            s.finish_evict(v.key, true);
        }
    }

    #[test]
    fn has_file_blobs_drop_for_free() {
        let s = WarmStore::new(10);
        let victims = s.put(key(1), blob(32), true);
        assert_eq!(victims.len(), 1);
        assert!(victims[0].has_file, "file mark rides the victim");
        s.finish_evict(key(1), false);
        assert_eq!(s.eviction_count(), 0, "free drop: no cold write counted");
    }

    #[test]
    fn remove_frees_bytes_even_mid_eviction() {
        let s = WarmStore::new(1 << 20);
        assert!(s.put(key(1), blob(64), false).is_empty());
        assert_eq!(s.remove(key(1)), Some(64));
        assert_eq!(s.resident_bytes(), 0);
        assert_eq!(s.remove(key(1)), None);
        // Mid-eviction removal never underflows the resident gauge.
        let s = WarmStore::new(10);
        let victims = s.put(key(2), blob(32), false);
        assert_eq!(victims.len(), 1);
        assert_eq!(s.remove(key(2)), Some(32));
        assert_eq!(s.resident_bytes(), 0);
        s.finish_evict(key(2), true);
        assert_eq!(s.resident_bytes(), 0);
    }

    #[test]
    fn get_or_fill_runs_the_encode_exactly_once() {
        // N racing threads get_or_fill the same key; the encode must run
        // once and everyone must see the same blob.
        let s = Arc::new(WarmStore::new(1 << 20));
        let encodes = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let s = Arc::clone(&s);
            let encodes = Arc::clone(&encodes);
            handles.push(std::thread::spawn(move || {
                let (b, victims) = s
                    .get_or_fill(key(5), || {
                        encodes.fetch_add(1, Ordering::SeqCst);
                        // Give racers time to pile onto the fill.
                        std::thread::sleep(std::time::Duration::from_millis(5));
                        Ok(Some((blob(128), false)))
                    })
                    .unwrap();
                assert!(victims.is_empty());
                b.unwrap().len()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 128);
        }
        assert_eq!(encodes.load(Ordering::SeqCst), 1, "fill must be once-only");
        assert_eq!(s.miss_count(), 1);
        assert_eq!(s.hit_count(), 7);
    }

    #[test]
    fn get_or_fill_none_inserts_nothing_and_unblocks_racers() {
        let s = Arc::new(WarmStore::new(1 << 20));
        let (b, v) = s.get_or_fill(key(1), || Ok(None)).unwrap();
        assert!(b.is_none() && v.is_empty());
        assert_eq!(s.len(), 0);
        // A later fill still works (no stuck `filling` marker).
        let (b, _) = s.get_or_fill(key(1), || Ok(Some((blob(8), false)))).unwrap();
        assert_eq!(b.unwrap().len(), 8);
        // Errors propagate and clear the marker too.
        assert!(s.get_or_fill(key(2), || anyhow::bail!("boom")).is_err());
        let (b, _) = s.get_or_fill(key(2), || Ok(Some((blob(4), false)))).unwrap();
        assert_eq!(b.unwrap().len(), 4);
    }

    #[test]
    fn oversized_fill_from_file_evicts_for_free() {
        // A blob slurped from an existing spill file carries `has_file`
        // through the fill: even when it overflows the budget immediately,
        // the eviction must not ask the caller to rewrite the file.
        let s = WarmStore::new(16);
        let (b, victims) = s.get_or_fill(key(1), || Ok(Some((blob(64), true)))).unwrap();
        assert_eq!(b.unwrap().len(), 64);
        assert_eq!(victims.len(), 1, "oversized fill self-evicts");
        assert!(victims[0].has_file, "file mark must ride the fill");
        s.finish_evict(key(1), false);
        assert_eq!(s.eviction_count(), 0, "free drop: no cold write");
    }
}
