//! The **cold tier**: the spill-file plane, plus its I/O accounting.
//!
//! Cold is where bytes go when neither in-memory tier may hold them: the
//! warm tier is off, its budget overflowed, or the runtime runs the pure
//! file plane (`--memory-budget 0`, byte-identical to the seed runtime).
//! The tier has no in-memory index of its own — a version's file path and
//! serialized size live in the
//! [`VersionTable`](crate::coordinator::registry::VersionTable) (published
//! under the owning shard lock, so a reader of a path can never observe a
//! torn write) — but it *does* own the file I/O counters the acceptance
//! tests pin: a memory-resident N-node fan-out transfer with the warm tier
//! on performs **zero** cold reads and writes.
//!
//! `ensure_file` is the demotion endpoint and the transfer plane's
//! fallback: it publishes a spill file from whichever tier holds the value
//! — warm blobs are written verbatim (the encode already happened), hot
//! values go through the codec.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::registry::{DataKey, VersionTable};
use crate::coordinator::runtime::Shared;
use crate::coordinator::store::{Tier, ValueStore};
use crate::value::RValue;

/// Cold-tier handle: file I/O counters plus a view of the version table
/// (which indexes the published files). All methods take `&self`.
pub struct ColdStore {
    table: Arc<VersionTable>,
    file_reads: AtomicU64,
    file_writes: AtomicU64,
}

impl ColdStore {
    pub fn new(table: Arc<VersionTable>) -> ColdStore {
        ColdStore {
            table,
            file_reads: AtomicU64::new(0),
            file_writes: AtomicU64::new(0),
        }
    }

    /// Count one parameter/spill-file read.
    pub(crate) fn note_read(&self) {
        self.file_reads.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one parameter/spill-file write.
    pub(crate) fn note_write(&self) {
        self.file_writes.fetch_add(1, Ordering::Relaxed);
    }

    /// Parameter/spill files read since startup.
    pub fn file_read_count(&self) -> u64 {
        self.file_reads.load(Ordering::Relaxed)
    }

    /// Parameter/spill files written since startup.
    pub fn file_write_count(&self) -> u64 {
        self.file_writes.load(Ordering::Relaxed)
    }

    /// Delete a published file (version GC). Per-tier residency tracking
    /// means the GC only asks for files that were actually published, so a
    /// failure here is a real leak and is reported loudly instead of being
    /// silently swallowed (the pre-tier runtime ignored the error).
    pub(crate) fn delete_file(&self, path: &Path) -> bool {
        match std::fs::remove_file(path) {
            Ok(()) => true,
            Err(e) => {
                eprintln!(
                    "[rcompss] gc: published spill file {} could not be deleted: {e}",
                    path.display()
                );
                false
            }
        }
    }
}

impl ValueStore for ColdStore {
    fn tier(&self) -> Tier {
        Tier::Cold
    }

    /// The filesystem is always there; "off" is not a cold-tier state.
    fn enabled(&self) -> bool {
        true
    }

    fn resident_bytes(&self) -> u64 {
        self.table.file_bytes()
    }

    fn entry_count(&self) -> usize {
        self.table.file_count()
    }

    fn contains(&self, key: DataKey) -> bool {
        self.table.path_of(key).is_some()
    }

    /// Trait-level discard: atomically take the version's published path
    /// out of the table (no reader can reach the file through a stale
    /// entry afterwards) and delete the file. The runtime GC does *not*
    /// route through this — it takes the path through `CollectAction` at
    /// collect time and calls [`ColdStore::delete_file`] directly.
    fn discard(&self, key: DataKey) -> Option<u64> {
        let (path, bytes) = self.table.take_path(key)?;
        if self.delete_file(&path) {
            Some(bytes)
        } else {
            None
        }
    }
}

/// Atomically publish a spill file for `key` through the codec: encode
/// into a uniquely-named temp file and rename it over the final `dXvY.par`
/// path. Racing spillers (an eviction and a spill-for-transfer of the
/// same version) then each publish a complete, identical file — a reader
/// of a published path can never observe a torn truncate-then-write.
pub(crate) fn write_spill_file(
    shared: &Shared,
    key: DataKey,
    value: &RValue,
) -> Result<(u64, PathBuf)> {
    let final_path = shared.path_for(key);
    let tmp = shared
        .workdir
        .join(format!("{key}.par.{}.tmp", crate::coordinator::runtime::unique_run_id()));
    shared.codec.write_file(value, &tmp)?;
    shared.store.note_encode();
    shared.store.cold().note_write();
    let bytes = std::fs::metadata(&tmp).map(|m| m.len()).unwrap_or(0);
    std::fs::rename(&tmp, &final_path)
        .with_context(|| format!("publish spill {}", final_path.display()))?;
    Ok((bytes, final_path))
}

/// Atomically publish a spill file from an already-encoded warm blob: the
/// bytes go down verbatim — the warm tier paid the codec, the cold tier
/// only pays the I/O. Same temp-and-rename protocol as
/// [`write_spill_file`].
pub(crate) fn publish_blob_file(
    shared: &Shared,
    key: DataKey,
    blob: &[u8],
) -> Result<(u64, PathBuf)> {
    let final_path = shared.path_for(key);
    let tmp = shared
        .workdir
        .join(format!("{key}.par.{}.tmp", crate::coordinator::runtime::unique_run_id()));
    std::fs::write(&tmp, blob).with_context(|| format!("write blob {}", tmp.display()))?;
    shared.store.cold().note_write();
    std::fs::rename(&tmp, &final_path)
        .with_context(|| format!("publish spill {}", final_path.display()))?;
    Ok((blob.len() as u64, final_path))
}

/// Make sure a serialized file exists for `key` and return its path: the
/// cold-tier fallback of the transfer plane (warm tier off) and the
/// synchronous claim-path reload. The file is published from the cheapest
/// tier that holds the value — a warm blob is written verbatim, a hot
/// value runs the codec.
pub(crate) fn ensure_file(shared: &Shared, key: DataKey) -> Result<PathBuf> {
    loop {
        if let Some(p) = shared.table.path_of(key) {
            return Ok(p);
        }
        if let Some(blob) = shared.store.warm().get(key) {
            let (bytes, path) = publish_blob_file(shared, key, &blob)?;
            if !shared.table.mark_spilled(key, bytes, path.clone()) {
                let _ = std::fs::remove_file(&path);
                anyhow::bail!("datum {key} was reclaimed by the version GC");
            }
            shared.store.hot().note_file(key);
            shared.store.warm().note_file(key);
            return Ok(path);
        }
        if let Some(v) = shared.store.hot().get(key) {
            let (bytes, path) = write_spill_file(shared, key, &v)?;
            if !shared.table.mark_spilled(key, bytes, path.clone()) {
                let _ = std::fs::remove_file(&path);
                anyhow::bail!("datum {key} was reclaimed by the version GC");
            }
            shared.store.hot().note_file(key);
            return Ok(path);
        }
        if shared.table.is_collected(key) {
            anyhow::bail!("datum {key} was reclaimed by the version GC");
        }
        if !shared.table.is_available(key) {
            // Lost with a dead node (no tier holds it, no file): error out
            // so the caller fails fast instead of spinning — lineage
            // recovery re-derives the version and retries converge.
            anyhow::bail!("datum {key} is unavailable (lost with a dead node)");
        }
        // Mid-demotion: the spill path is about to be published.
        std::thread::yield_now();
    }
}
