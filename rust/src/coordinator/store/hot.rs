//! The **hot tier**: a byte-budgeted cache of decoded `Arc<RValue>`s.
//!
//! COMPSs (and the seed version of this runtime) passes *every* task
//! parameter through a serialized file, even when producer and consumer are
//! threads of the same process on the same node. The paper's efficiency
//! argument (§4) rests on runtime overhead staying small relative to task
//! granularity; for fine-grained tasks the encode→write→read→decode
//! round-trip *is* the overhead. The [`DataStore`] removes it: produced
//! values are kept as `Arc<RValue>` keyed by their `dXvY` [`DataKey`], so a
//! node-local consumer receives a zero-copy handle and the configured codec
//! runs only at *tier boundaries*:
//!
//! * **memory pressure** — the store holds at most `budget` bytes; overflow
//!   evicts victims (LRU or largest-first per [`SpillPolicy`]) which are
//!   demoted down the tier ladder by `super::demote_victims`: encoded
//!   into the warm tier when it is on, serialized to a cold spill file
//!   otherwise (exactly what the pre-tier runtime did);
//! * **cross-node transfer** — a consumer on another (emulated) node forces
//!   the value through the codec, keeping multi-node runs honest;
//! * **explicit fetch** — `wait_on` of an evicted value reloads it from the
//!   warm blob (no disk) or its spill file.
//!
//! A budget of 0 disables the store entirely (the warm tier follows),
//! restoring the seed's byte-identical file-based behavior (every codec
//! round-trip property test runs against that path unchanged).
//!
//! ## Concurrency protocol
//!
//! The store is a sharded-lock-free *consumer* but a mutexed *container*:
//! `get` clones an `Arc` under a short lock; eviction is two-phase so a
//! value is always reachable. `put` selects victims and marks them
//! `spilling` (still readable), the caller runs the codec *outside* the
//! lock, publishes the warm blob or the file path in the
//! [`VersionTable`](crate::coordinator::registry::VersionTable), and only
//! then calls [`DataStore::finish_spill`] to drop the cached copy. A
//! concurrent reader therefore always finds the value in a tier or at a
//! published path — never nowhere.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::registry::DataKey;
use crate::coordinator::store::{Tier, ValueStore};
use crate::value::RValue;

/// Which victim the store picks when over budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpillPolicy {
    /// Least-recently-used first (default) — favors hot working sets.
    Lru,
    /// Largest entry first — frees the budget in the fewest codec calls.
    Largest,
}

impl SpillPolicy {
    /// Parse a config string (`"lru"` | `"largest"`).
    pub fn by_name(name: &str) -> Option<SpillPolicy> {
        match name {
            "lru" => Some(SpillPolicy::Lru),
            "largest" => Some(SpillPolicy::Largest),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpillPolicy::Lru => "lru",
            SpillPolicy::Largest => "largest",
        }
    }
}

/// A value selected for demotion: still readable in the store until the
/// caller lands its bytes in a lower tier and calls
/// [`DataStore::finish_spill`].
pub struct SpillVictim {
    pub key: DataKey,
    pub value: Arc<RValue>,
    /// The value already has an up-to-date spill file (it was reloaded from
    /// one); the caller may skip the codec and just `finish_spill`.
    pub has_file: bool,
}

struct Entry {
    value: Arc<RValue>,
    bytes: u64,
    last_used: u64,
    /// Selected as a spill victim; excluded from further selection and from
    /// the resident-byte total, but still served by `get`.
    spilling: bool,
    /// An up-to-date serialized file for this version already exists.
    has_file: bool,
}

#[derive(Default)]
struct Inner {
    map: HashMap<DataKey, Entry>,
    /// Bytes held by entries not currently being spilled.
    resident: u64,
}

/// The hot in-memory object store. All methods take `&self`; a budget of 0
/// makes every operation a cheap no-op (file plane).
pub struct DataStore {
    budget: u64,
    policy: SpillPolicy,
    tick: AtomicU64,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    spills: AtomicU64,
    spill_bytes: AtomicU64,
    /// High-water mark of `resident` — the gauge the window compiler's
    /// aliasing claim is measured against: an AOT-released dying input
    /// freed before its consumer's output lands keeps the peak flat.
    peak_resident: AtomicU64,
    /// Cross-node consumptions that ran the codec *synchronously on the
    /// claim path* (the seed behavior). With the async transfer service on,
    /// this stays zero: movers run the codec, claimants get staged bytes.
    sync_transfer_decodes: AtomicU64,
}

impl DataStore {
    pub fn new(budget: u64, policy: SpillPolicy) -> DataStore {
        DataStore {
            budget,
            policy,
            tick: AtomicU64::new(0),
            inner: Mutex::new(Inner::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            spill_bytes: AtomicU64::new(0),
            peak_resident: AtomicU64::new(0),
            sync_transfer_decodes: AtomicU64::new(0),
        }
    }

    /// A disabled store (budget 0): the runtime uses the file plane only.
    pub fn disabled() -> DataStore {
        DataStore::new(0, SpillPolicy::Lru)
    }

    /// Is the in-memory plane active?
    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Insert a produced value and return any victims that must be demoted
    /// to stay within budget (possibly including the value just inserted,
    /// when it alone exceeds the budget). The caller must land each victim
    /// in a lower tier (see `super::demote_victims`), then call
    /// [`DataStore::finish_spill`].
    ///
    /// `has_file` marks values whose serialized file already exists (a
    /// reload, or a replica staged from a version that also has a cold
    /// file), whose eviction is free.
    #[must_use = "victims must be demoted and finish_spill()ed"]
    pub fn put(&self, key: DataKey, value: Arc<RValue>, has_file: bool) -> Vec<SpillVictim> {
        if !self.enabled() {
            return Vec::new();
        }
        let bytes = value.byte_size() as u64;
        let now = self.tick.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let entry = Entry {
            value,
            bytes,
            last_used: now,
            spilling: false,
            has_file,
        };
        if let Some(old) = inner.map.insert(key, entry) {
            // Re-insert of the same version (e.g. a reload racing another
            // reader): keep byte accounting consistent.
            if !old.spilling {
                inner.resident = inner.resident.saturating_sub(old.bytes);
            }
        }
        inner.resident += bytes;
        self.peak_resident.fetch_max(inner.resident, Ordering::Relaxed);

        let mut victims = Vec::new();
        while inner.resident > self.budget {
            let pick = match self.policy {
                SpillPolicy::Lru => inner
                    .map
                    .iter()
                    .filter(|(_, e)| !e.spilling)
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| *k),
                SpillPolicy::Largest => inner
                    .map
                    .iter()
                    .filter(|(_, e)| !e.spilling)
                    .max_by_key(|(_, e)| e.bytes)
                    .map(|(k, _)| *k),
            };
            let Some(k) = pick else { break };
            let e = inner.map.get_mut(&k).expect("victim entry");
            e.spilling = true;
            inner.resident = inner.resident.saturating_sub(e.bytes);
            victims.push(SpillVictim {
                key: k,
                value: Arc::clone(&e.value),
                has_file: e.has_file,
            });
        }
        victims
    }

    /// Zero-copy lookup; bumps recency and the hit/miss counters.
    pub fn get(&self, key: DataKey) -> Option<Arc<RValue>> {
        if !self.enabled() {
            return None;
        }
        let now = self.tick.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        match inner.map.get_mut(&key) {
            Some(e) => {
                e.last_used = now;
                let v = Arc::clone(&e.value);
                drop(inner);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                drop(inner);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Peek without touching recency or counters (tests, stats).
    pub fn contains(&self, key: DataKey) -> bool {
        self.enabled() && self.inner.lock().unwrap().map.contains_key(&key)
    }

    /// Drop a demoted entry once its bytes landed in a lower tier (warm
    /// blob inserted or file path published). `encoded` marks demotions
    /// that actually ran the codec — counted as a spill of
    /// `encoded_bytes` serialized bytes — as opposed to free evictions
    /// whose bytes were already down-tier. If a concurrent `put`
    /// re-inserted a fresh (non-spilling) entry for the same version in
    /// the meantime — a cross-node reload racing the eviction — that entry
    /// is left in place: it is separately accounted in `resident` and
    /// removing it would both leak the counter and drop a live cache line.
    pub fn finish_spill(&self, key: DataKey, encoded: bool, encoded_bytes: u64) {
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.map.get(&key).map(|e| e.spilling).unwrap_or(false) {
                inner.map.remove(&key);
            }
        }
        if encoded {
            self.spills.fetch_add(1, Ordering::Relaxed);
            self.spill_bytes.fetch_add(encoded_bytes, Ordering::Relaxed);
        }
    }

    /// Undo a victim selection after a failed demotion, so the value
    /// stays reachable and evictable.
    pub fn abort_spill(&self, key: DataKey) {
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        if let Some(e) = inner.map.get_mut(&key) {
            if e.spilling {
                e.spilling = false;
                inner.resident += e.bytes;
            }
        }
    }

    /// Drop a version the GC reclaimed: the entry disappears immediately
    /// (no two-phase dance — the caller guarantees no consumer reference
    /// remains). Returns the payload bytes freed. An entry mid-spill is
    /// removed too; its in-flight demotion finishes harmlessly against
    /// a missing entry.
    pub fn remove(&self, key: DataKey) -> Option<u64> {
        if !self.enabled() {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        match inner.map.remove(&key) {
            Some(e) => {
                if !e.spilling {
                    inner.resident = inner.resident.saturating_sub(e.bytes);
                }
                Some(e.bytes)
            }
            None => None,
        }
    }

    /// Count a synchronous cross-node codec round-trip on a claim path
    /// (the fallback when the transfer service is disabled or a transfer
    /// failed). The async-transfer acceptance tests assert this is zero.
    pub fn note_sync_transfer_decode(&self) {
        self.sync_transfer_decodes.fetch_add(1, Ordering::Relaxed);
    }

    /// Synchronous cross-node codec round-trips taken on claim paths.
    pub fn sync_transfer_decode_count(&self) -> u64 {
        self.sync_transfer_decodes.load(Ordering::Relaxed)
    }

    /// Mark that an up-to-date serialized file now exists for a cached
    /// value (spill-for-transfer keeps the value resident).
    pub fn note_file(&self, key: DataKey) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner.map.get_mut(&key) {
            e.has_file = true;
        }
    }

    pub fn resident_bytes(&self) -> u64 {
        self.inner.lock().unwrap().resident
    }

    /// High-water mark of resident bytes over the store's lifetime.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.peak_resident.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hit_count(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn miss_count(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn spill_count(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    pub fn spilled_bytes(&self) -> u64 {
        self.spill_bytes.load(Ordering::Relaxed)
    }
}

impl ValueStore for DataStore {
    fn tier(&self) -> Tier {
        Tier::Hot
    }

    fn enabled(&self) -> bool {
        DataStore::enabled(self)
    }

    fn resident_bytes(&self) -> u64 {
        DataStore::resident_bytes(self)
    }

    fn entry_count(&self) -> usize {
        self.len()
    }

    fn contains(&self, key: DataKey) -> bool {
        DataStore::contains(self, key)
    }

    fn discard(&self, key: DataKey) -> Option<u64> {
        self.remove(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::DataId;

    fn key(d: u64, v: u32) -> DataKey {
        DataKey {
            data: DataId(d),
            version: v,
        }
    }

    fn val(n: usize) -> Arc<RValue> {
        Arc::new(RValue::Real(vec![1.0; n]))
    }

    #[test]
    fn disabled_store_is_inert() {
        let s = DataStore::disabled();
        assert!(!s.enabled());
        assert!(s.put(key(1, 1), val(8), false).is_empty());
        assert!(s.get(key(1, 1)).is_none());
        assert_eq!(s.len(), 0);
        // A disabled store records no traffic at all.
        assert_eq!(s.hit_count() + s.miss_count(), 0);
    }

    #[test]
    fn put_get_roundtrip_is_zero_copy() {
        let s = DataStore::new(1 << 20, SpillPolicy::Lru);
        let v = val(10);
        assert!(s.put(key(1, 1), Arc::clone(&v), false).is_empty());
        let got = s.get(key(1, 1)).unwrap();
        assert!(Arc::ptr_eq(&v, &got), "get must return the same allocation");
        assert_eq!(s.hit_count(), 1);
        assert!(s.get(key(9, 9)).is_none());
        assert_eq!(s.miss_count(), 1);
    }

    #[test]
    fn lru_evicts_the_oldest_untouched_entry() {
        // Budget fits two 80-byte vectors; the third insert evicts the LRU.
        let s = DataStore::new(170, SpillPolicy::Lru);
        assert!(s.put(key(1, 1), val(10), false).is_empty());
        assert!(s.put(key(2, 1), val(10), false).is_empty());
        // Touch 1 so 2 becomes the LRU victim.
        s.get(key(1, 1)).unwrap();
        let victims = s.put(key(3, 1), val(10), false);
        assert_eq!(victims.len(), 1);
        assert_eq!(victims[0].key, key(2, 1));
        // Victim is still readable until finish_spill (two-phase eviction).
        assert!(s.get(key(2, 1)).is_some());
        s.finish_spill(key(2, 1), true, 80);
        assert!(s.get(key(2, 1)).is_none());
        assert_eq!(s.spill_count(), 1);
        assert_eq!(s.spilled_bytes(), 80);
        assert!(s.resident_bytes() <= 170);
    }

    #[test]
    fn largest_policy_evicts_by_size() {
        let s = DataStore::new(200, SpillPolicy::Largest);
        assert!(s.put(key(1, 1), val(2), false).is_empty()); // 16 B
        assert!(s.put(key(2, 1), val(20), false).is_empty()); // 160 B
        let victims = s.put(key(3, 1), val(5), false); // 40 B -> over budget
        assert_eq!(victims.len(), 1);
        assert_eq!(victims[0].key, key(2, 1), "largest entry goes first");
        s.finish_spill(key(2, 1), true, 160);
    }

    #[test]
    fn oversized_value_spills_itself() {
        let s = DataStore::new(64, SpillPolicy::Lru);
        let victims = s.put(key(1, 1), val(100), false); // 800 B > budget
        assert_eq!(victims.len(), 1);
        assert_eq!(victims[0].key, key(1, 1));
        // Still readable until the spill completes.
        assert!(s.get(key(1, 1)).is_some());
        s.finish_spill(key(1, 1), true, 800);
        assert!(s.is_empty());
    }

    #[test]
    fn abort_spill_restores_the_entry() {
        let s = DataStore::new(100, SpillPolicy::Lru);
        let victims = s.put(key(1, 1), val(50), false);
        assert_eq!(victims.len(), 1);
        s.abort_spill(key(1, 1));
        assert_eq!(s.resident_bytes(), 400);
        // The entry is a candidate again on the next overflow.
        let victims = s.put(key(2, 1), val(1), false);
        assert!(victims.iter().any(|v| v.key == key(1, 1)));
        for v in victims {
            s.finish_spill(v.key, true, 1);
        }
    }

    #[test]
    fn reloaded_entries_evict_without_recount() {
        let s = DataStore::new(100, SpillPolicy::Lru);
        let victims = s.put(key(1, 1), val(50), true); // reloaded from file
        assert_eq!(victims.len(), 1);
        assert!(victims[0].has_file, "reload carries the has_file mark");
        s.finish_spill(key(1, 1), false, 0); // free eviction: no codec ran
        assert_eq!(s.spill_count(), 0);
    }

    #[test]
    fn remove_frees_bytes_and_counts_nothing() {
        let s = DataStore::new(1 << 20, SpillPolicy::Lru);
        assert!(s.put(key(1, 1), val(10), false).is_empty());
        assert_eq!(s.resident_bytes(), 80);
        assert_eq!(s.remove(key(1, 1)), Some(80));
        assert_eq!(s.resident_bytes(), 0);
        assert!(s.get(key(1, 1)).is_none());
        // Removing again (or an unknown key) is a no-op.
        assert_eq!(s.remove(key(1, 1)), None);
        assert_eq!(s.spill_count(), 0, "GC removal is not a spill");
    }

    #[test]
    fn remove_of_spilling_entry_does_not_underflow_resident() {
        let s = DataStore::new(100, SpillPolicy::Lru);
        // 400 B value over a 100 B budget: immediately selected for spill,
        // which already deducted it from `resident`.
        let victims = s.put(key(1, 1), val(50), false);
        assert_eq!(victims.len(), 1);
        assert_eq!(s.remove(key(1, 1)), Some(400));
        assert_eq!(s.resident_bytes(), 0);
        // The in-flight spill completion finds nothing and stays harmless.
        s.finish_spill(key(1, 1), true, 400);
        assert_eq!(s.resident_bytes(), 0);
    }

    #[test]
    fn sync_transfer_decode_counter_ticks() {
        let s = DataStore::new(1 << 20, SpillPolicy::Lru);
        assert_eq!(s.sync_transfer_decode_count(), 0);
        s.note_sync_transfer_decode();
        s.note_sync_transfer_decode();
        assert_eq!(s.sync_transfer_decode_count(), 2);
    }

    #[test]
    fn versions_are_distinct_keys() {
        let s = DataStore::new(1 << 20, SpillPolicy::Lru);
        let v1 = val(1);
        let v2 = Arc::new(RValue::Real(vec![2.0]));
        assert!(s.put(key(1, 1), Arc::clone(&v1), false).is_empty());
        assert!(s.put(key(1, 2), Arc::clone(&v2), false).is_empty());
        assert!(Arc::ptr_eq(&s.get(key(1, 1)).unwrap(), &v1));
        assert!(Arc::ptr_eq(&s.get(key(1, 2)).unwrap(), &v2));
    }

    #[test]
    fn concurrent_produce_consume_across_versions() {
        // N producer threads publish distinct versions while N consumers
        // spin until they observe each one; the store must never lose or
        // mix up a version. Budget is tight enough to force evictions.
        let s = Arc::new(DataStore::new(4096, SpillPolicy::Lru));
        let versions: u32 = 40;
        let data: u64 = 7;
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for v in 1..=versions {
                    if (u64::from(v) % 4) == t {
                        let value = Arc::new(RValue::Real(vec![f64::from(v); 32]));
                        for victim in s.put(key(data, v), value, false) {
                            // Test stand-in for the runtime's codec demotion.
                            s.finish_spill(victim.key, true, victim.value.byte_size() as u64);
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Every surviving resident version must carry its own payload.
        let mut seen = 0;
        for v in 1..=versions {
            if let Some(got) = s.get(key(data, v)) {
                assert_eq!(got.as_real().unwrap()[0], f64::from(v), "version {v} mixed up");
                seen += 1;
            }
        }
        assert!(seen > 0, "some versions must remain resident");
        assert!(s.resident_bytes() <= 4096 + 32 * 8);
    }
}
