//! Minimal JSON parser + writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`), machine-profile config files, and trace
//! metadata. Hand-rolled because the offline vendor set has no serde; it
//! supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) which is all the manifests need.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys keep sorted order (BTreeMap) so output
/// is deterministic — handy for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document; trailing whitespace allowed,
    /// trailing garbage is an error.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

/// Build a `Json::Obj` from pairs — small helper for writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let c =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00));
                                char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(ch);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12e2").unwrap(), Json::Num(-1200.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": true}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let doc = r#"{"name":"knn_dist","shape":[500,50],"ok":true,"x":null}"#;
        let v = Json::parse(doc).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn get_on_missing_returns_null() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        assert_eq!(v.get("zzz"), &Json::Null);
        assert_eq!(v.get("a").as_usize(), Some(1));
    }
}
