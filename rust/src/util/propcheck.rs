//! Miniature property-testing harness (proptest is not in the offline
//! vendor set). It generates seeded random cases, runs a property, and on
//! failure re-reports the seed so the case can be replayed exactly.
//!
//! The coordinator invariant tests (`rust/tests/prop_coordinator.rs`) are
//! built on this: random task graphs in, schedule-validity invariants out.

use crate::util::prng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; case `i` uses stream `i`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // RCOMPSS_PROP_CASES / RCOMPSS_PROP_SEED allow widening or replaying
        // from the environment without recompiling.
        let cases = std::env::var("RCOMPSS_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("RCOMPSS_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config { cases, seed }
    }
}

/// Run `prop` against `cases` generated inputs. `gen` receives a per-case
/// PRNG; `prop` returns `Err(reason)` to fail. Panics with the seed and the
/// case debug representation on failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: &Config,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let mut rng = Pcg64::new(cfg.seed, case as u64);
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property '{name}' failed\n  case:   {case}\n  seed:   {} (set RCOMPSS_PROP_SEED to replay)\n  reason: {reason}\n  input:  {input:?}",
                cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        check(
            "u64 is even after doubling",
            &Config { cases: 32, seed: 1 },
            |r| r.next_u64() / 2 * 2,
            |x| {
                if x % 2 == 0 {
                    Ok(())
                } else {
                    Err("odd".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures() {
        check(
            "always fails",
            &Config { cases: 4, seed: 2 },
            |r| r.next_u64(),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn cases_are_reproducible() {
        let mut first: Vec<u64> = Vec::new();
        check(
            "collect",
            &Config { cases: 8, seed: 9 },
            |r| r.next_u64(),
            |x| {
                first.push(*x);
                Ok(())
            },
        );
        let mut second: Vec<u64> = Vec::new();
        check(
            "collect again",
            &Config { cases: 8, seed: 9 },
            |r| r.next_u64(),
            |x| {
                second.push(*x);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}
