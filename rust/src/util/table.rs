//! Fixed-width table printer used by the bench harness to render
//! paper-style result tables (Table 1 and the per-figure series) on stdout
//! and into EXPERIMENTS.md-ready markdown.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Table {
        self.title = Some(title.to_string());
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(c);
                for _ in c.chars().count()..w[i] {
                    s.push(' ');
                }
            }
            s.trim_end().to_string()
        };
        out.push_str(&line(&self.header, &w));
        out.push('\n');
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavored markdown (pasted into EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("**{t}**\n\n"));
        }
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds with adaptive precision (matches the paper's tables:
/// "0.45", "131.01").
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.1}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else {
        format!("{s:.3}")
    }
}

/// Format an efficiency fraction as a percentage ("70%", "43%").
pub fn fmt_pct(e: f64) -> String {
    format!("{:.0}%", e * 100.0)
}

/// Format bytes at a human scale.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Method", "S", "D"]);
        t.row(vec!["RMVL".into(), "0.45".into(), "0.66".into()]);
        t.row(vec!["RDS".into(), "31.85".into(), "4.51".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines[0].starts_with("Method"));
        assert!(lines[1].starts_with("---"));
        assert_eq!(lines.len(), 4);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_secs(131.012), "131.0");
        assert_eq!(fmt_secs(31.853), "31.85");
        assert_eq!(fmt_secs(0.4531), "0.453");
        assert_eq!(fmt_pct(0.704), "70%");
        assert_eq!(fmt_bytes(10), "10 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
