//! Tiny duration formatting helpers for logs and the trace renderer.

/// Format a duration in seconds adaptively: "830µs", "12.3ms", "4.56s",
/// "2m03s", "1h02m".
pub fn format_duration_s(secs: f64) -> String {
    if secs < 0.0 {
        return format!("-{}", format_duration_s(-secs));
    }
    if secs < 1e-3 {
        format!("{:.0}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1}ms", secs * 1e3)
    } else if secs < 60.0 {
        format!("{secs:.2}s")
    } else if secs < 3600.0 {
        let m = (secs / 60.0).floor();
        format!("{}m{:02.0}s", m as u64, secs - m * 60.0)
    } else {
        let h = (secs / 3600.0).floor();
        let m = ((secs - h * 3600.0) / 60.0).floor();
        format!("{}h{:02}m", h as u64, m as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales() {
        assert_eq!(format_duration_s(0.0000014), "1µs");
        assert_eq!(format_duration_s(0.0123), "12.3ms");
        assert_eq!(format_duration_s(4.561), "4.56s");
        assert_eq!(format_duration_s(123.0), "2m03s");
        assert_eq!(format_duration_s(3720.0), "1h02m");
        assert_eq!(format_duration_s(-2.0), "-2.00s");
    }
}
