//! PCG-XSL-RR 128/64 pseudo-random generator.
//!
//! A small, fast, statistically solid PRNG used everywhere the runtime needs
//! deterministic randomness: synthetic fragment generation in the benchmark
//! apps (the paper generates data on the fly inside `fill_fragment` tasks),
//! scheduler tie-breaking experiments, failure injection, and the property
//! test harness. Seeded runs are exactly reproducible across platforms.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different stream ids
    /// yield statistically independent sequences for the same seed, which
    /// the apps use to give every fragment its own stream (`stream = frag`).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller (cached second draw is deliberately
    /// *not* kept: branch-free hot loops matter more than halving the
    /// trig count here).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless bounded sampling.
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Fill a slice with uniform [0,1) doubles.
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.next_f64();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Pcg64::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Pcg64::seeded(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
