//! Descriptive statistics for the bench harness: mean, stddev, median,
//! percentiles, and the parallel-efficiency metrics the paper reports.

/// Summary of a sample of measurements (seconds, bytes/s, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Strong-scaling parallel efficiency: `T1 / (p * Tp)`.
///
/// This is the metric behind Figures 7 and 9 ("KNN maintains parallel
/// efficiency of 44% on Shaheen-III ... at 32 nodes").
pub fn strong_efficiency(t1: f64, tp: f64, p: f64) -> f64 {
    t1 / (p * tp)
}

/// Weak-scaling parallel efficiency: `T1 / Tp` with the problem size grown
/// proportionally to `p` (Figures 6 and 8).
pub fn weak_efficiency(t1: f64, tp: f64) -> f64 {
    t1 / tp
}

/// Speedup `T1 / Tp`.
pub fn speedup(t1: f64, tp: f64) -> f64 {
    t1 / tp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 5.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn efficiency_definitions() {
        // Ideal strong scaling: p cores -> T/p.
        assert!((strong_efficiency(100.0, 25.0, 4.0) - 1.0).abs() < 1e-12);
        // Half-efficient.
        assert!((strong_efficiency(100.0, 50.0, 4.0) - 0.5).abs() < 1e-12);
        // Ideal weak scaling: time constant.
        assert!((weak_efficiency(10.0, 10.0) - 1.0).abs() < 1e-12);
        assert!((weak_efficiency(10.0, 20.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn single_sample_summary() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p95, 7.0);
    }
}
