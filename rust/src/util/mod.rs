//! Shared utilities for the runtime.
//!
//! Everything here is hand-rolled because the build is fully offline: a JSON
//! parser/writer (artifact manifests, run configs, trace metadata), a PCG
//! pseudo-random generator (deterministic workload generation), descriptive
//! statistics for the bench harness, a fixed-width table printer that
//! renders the paper-style result tables, byte-level transforms used by the
//! serialization codecs, and a miniature property-testing harness used by
//! the coordinator invariant tests.

pub mod bytes;
pub mod humantime;
pub mod json;
pub mod prng;
pub mod propcheck;
pub mod stats;
pub mod table;

pub use humantime::format_duration_s;
pub use prng::Pcg64;
