//! Byte-level transforms shared by the serialization codecs:
//! little/big-endian primitive packing and the byte-shuffle (transpose)
//! filter that the `qs`-style codec applies before LZ compression.

/// Append a little-endian u64.
#[inline]
pub fn put_u64_le(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a little-endian u32.
#[inline]
pub fn put_u32_le(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Read a little-endian u64 at `off`, advancing it.
#[inline]
pub fn get_u64_le(buf: &[u8], off: &mut usize) -> Option<u64> {
    let b = buf.get(*off..*off + 8)?;
    *off += 8;
    Some(u64::from_le_bytes(b.try_into().unwrap()))
}

/// Read a little-endian u32 at `off`, advancing it.
#[inline]
pub fn get_u32_le(buf: &[u8], off: &mut usize) -> Option<u32> {
    let b = buf.get(*off..*off + 4)?;
    *off += 4;
    Some(u32::from_le_bytes(b.try_into().unwrap()))
}

/// Reinterpret an f64 slice as raw little-endian bytes (copy).
pub fn f64s_to_le_bytes(xs: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Parse raw little-endian bytes into f64s; `None` if not a multiple of 8.
pub fn le_bytes_to_f64s(buf: &[u8]) -> Option<Vec<f64>> {
    if buf.len() % 8 != 0 {
        return None;
    }
    Some(
        buf.chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect(),
    )
}

/// Byte-shuffle filter: for `width`-byte elements, groups byte 0 of every
/// element, then byte 1 of every element, etc. Floating-point data has
/// highly repetitive exponent bytes, so shuffling dramatically improves LZ
/// compressibility — this is the trick behind `qs` (and Blosc).
pub fn shuffle(data: &[u8], width: usize) -> Vec<u8> {
    assert!(width > 0);
    let n = data.len() / width;
    let tail = &data[n * width..];
    let mut out = Vec::with_capacity(data.len());
    for b in 0..width {
        for i in 0..n {
            out.push(data[i * width + b]);
        }
    }
    out.extend_from_slice(tail);
    out
}

/// Inverse of [`shuffle`].
pub fn unshuffle(data: &[u8], width: usize) -> Vec<u8> {
    assert!(width > 0);
    let n = data.len() / width;
    let body = n * width;
    let tail = &data[body..];
    let mut out = vec![0u8; body];
    for b in 0..width {
        for i in 0..n {
            out[i * width + b] = data[b * n + i];
        }
    }
    out.extend_from_slice(tail);
    out
}

/// CRC32 (IEEE, reflected) — used by the RMVL-like codec footer to detect
/// torn writes, mirroring checksummed object stores.
pub fn crc32(data: &[u8]) -> u32 {
    // Tiny table-driven implementation; table built once.
    static TABLE: once_cell::sync::Lazy<[u32; 256]> = once_cell::sync::Lazy::new(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = !0u32;
    for &b in data {
        crc = TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_roundtrip() {
        let mut buf = Vec::new();
        put_u64_le(&mut buf, 0xDEAD_BEEF_CAFE_F00D);
        put_u32_le(&mut buf, 7);
        let mut off = 0;
        assert_eq!(get_u64_le(&buf, &mut off), Some(0xDEAD_BEEF_CAFE_F00D));
        assert_eq!(get_u32_le(&buf, &mut off), Some(7));
        assert_eq!(get_u32_le(&buf, &mut off), None);
    }

    #[test]
    fn f64_bytes_roundtrip() {
        let xs = vec![1.5, -2.25, f64::MAX, f64::MIN_POSITIVE, 0.0];
        let bytes = f64s_to_le_bytes(&xs);
        assert_eq!(le_bytes_to_f64s(&bytes).unwrap(), xs);
        assert!(le_bytes_to_f64s(&bytes[..7]).is_none());
    }

    #[test]
    fn shuffle_roundtrip_with_tail() {
        let data: Vec<u8> = (0..35).collect(); // 4 elems of 8 + 3 tail
        let sh = shuffle(&data, 8);
        assert_eq!(unshuffle(&sh, 8), data);
        assert_ne!(sh, data);
    }

    #[test]
    fn shuffle_groups_bytes() {
        // elements [0,1], [2,3] width 2 -> [0,2,1,3]
        assert_eq!(shuffle(&[0, 1, 2, 3], 2), vec![0, 2, 1, 3]);
    }

    #[test]
    fn crc32_known_vector() {
        // Standard test vector: CRC32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
