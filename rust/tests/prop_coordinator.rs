//! Property-based tests on coordinator invariants (random task graphs).
//!
//! Uses the crate's seeded `propcheck` harness: each case generates a
//! random DAG workload, executes it (pure graph or live runtime), and
//! checks the invariants that make a superscalar runtime correct:
//! completion order respects dependencies, versions are monotone, every
//! scheduled task was ready, and random graphs always drain.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use rcompss::api::{CompssRuntime, RuntimeConfig, TaskArg, TaskDef};
use rcompss::coordinator::dag::{EdgeKind, TaskGraph, TaskId, TaskState};
use rcompss::coordinator::feedback::{AdaptivePlacement, FeedbackStats};
use rcompss::coordinator::placement::{placement_by_name, PlacementModel, RoutedReady};
use rcompss::coordinator::registry::{DataKey, DataRegistry, NodeId};
use rcompss::coordinator::scheduler::{scheduler_by_name, ReadyTask, ShardedReady};
use rcompss::util::propcheck::{check, Config};
use rcompss::util::prng::Pcg64;
use rcompss::value::RValue;

/// True when the CI chaos matrix is driving this run (`RCOMPSS_CHAOS`):
/// injected task/transfer failures and node kills perturb the performance
/// counters, so strict counter checks step aside — result exactness stays
/// in force, which is what the matrix is for.
fn chaos_active() -> bool {
    std::env::var("RCOMPSS_CHAOS").map_or(false, |v| {
        rcompss::coordinator::fault::ChaosSpec::parse(&v)
            .map_or(false, |s| s.is_active())
    })
}

/// A random DAG description: for each task, the set of earlier tasks it
/// reads from.
#[derive(Debug, Clone)]
struct RandomDag {
    deps: Vec<Vec<usize>>,
}

fn gen_dag(rng: &mut Pcg64, max_tasks: usize) -> RandomDag {
    let n = 2 + rng.below_usize(max_tasks - 1);
    let mut deps = Vec::with_capacity(n);
    for i in 0..n {
        let mut d = Vec::new();
        if i > 0 {
            let k = rng.below_usize(3.min(i) + 1);
            for _ in 0..k {
                d.push(rng.below_usize(i));
            }
            d.sort_unstable();
            d.dedup();
        }
        deps.push(d);
    }
    RandomDag { deps }
}

/// Drive a RandomDag through the pure TaskGraph with a scheduler, checking
/// every dispatch was legal and the graph drains.
fn run_pure(dag: &RandomDag, policy: &str) -> Result<(), String> {
    let mut graph = TaskGraph::new();
    let mut registry = DataRegistry::new();
    let mut scheduler = scheduler_by_name(policy).expect("policy");
    let mut out_keys: Vec<DataKey> = Vec::new();
    let mut ready: Vec<TaskId> = Vec::new();
    let mut ids = Vec::new();

    for (i, dd) in dag.deps.iter().enumerate() {
        let id = graph.next_task_id();
        ids.push(id);
        let mut deps = Vec::new();
        let mut reads = Vec::new();
        for &j in dd {
            let key = out_keys[j];
            let (k, raw) = registry.record_read(key.data, id);
            if let Some(p) = raw {
                deps.push((p, EdgeKind::Raw, k));
            }
            reads.push(k);
        }
        let out = registry.new_future(id);
        out_keys.push(out);
        let is_ready = graph.insert_task(id, &format!("t{i}"), reads, vec![out], deps);
        if is_ready {
            ready.push(id);
        }
    }
    for id in ready {
        scheduler.push(ReadyTask {
            id,
            inputs: vec![],
            type_name: "x".into(),
        });
    }

    let mut done: HashSet<TaskId> = HashSet::new();
    let idx: HashMap<TaskId, usize> = ids.iter().enumerate().map(|(i, t)| (*t, i)).collect();
    while let Some(id) = scheduler.pop_for(NodeId(0)) {
        // Invariant: every dependency of a dispatched task is done.
        let i = idx[&id];
        for &j in &dag.deps[i] {
            if !done.contains(&ids[j]) {
                return Err(format!("task {i} dispatched before dependency {j}"));
            }
        }
        if graph.state(id) != Some(TaskState::Ready) {
            return Err(format!("task {i} dispatched while not ready"));
        }
        graph.start(id);
        registry.mark_available(out_keys[i], NodeId(0), 1, Default::default());
        done.insert(id);
        for t in graph.complete(id) {
            scheduler.push(ReadyTask {
                id: t,
                inputs: vec![],
                type_name: "x".into(),
            });
        }
    }
    if !graph.quiescent() {
        return Err(format!(
            "graph did not drain: {}/{} done",
            graph.done_count(),
            dag.deps.len()
        ));
    }
    Ok(())
}

#[test]
fn prop_random_dags_drain_under_every_policy() {
    for policy in ["fifo", "lifo", "locality"] {
        check(
            &format!("random dags drain [{policy}]"),
            &Config::default(),
            |rng| gen_dag(rng, 40),
            |dag| run_pure(dag, policy),
        );
    }
}

#[test]
fn prop_critical_path_bounds() {
    check(
        "critical path is within [1, n] and >= longest chain",
        &Config::default(),
        |rng| gen_dag(rng, 30),
        |dag| {
            let mut graph = TaskGraph::new();
            let mut ids = Vec::new();
            let key = |d: u64| DataKey {
                data: rcompss::coordinator::registry::DataId(d),
                version: 1,
            };
            for (i, dd) in dag.deps.iter().enumerate() {
                let id = graph.next_task_id();
                let deps = dd
                    .iter()
                    .map(|j| (ids[*j], EdgeKind::Raw, key(*j as u64 + 1)))
                    .collect();
                graph.insert_task(id, &format!("t{i}"), vec![], vec![], deps);
                ids.push(id);
            }
            let cp = graph.critical_path_len();
            let n = dag.deps.len();
            if cp == 0 || cp > n {
                return Err(format!("critical path {cp} outside [1, {n}]"));
            }
            // Depth computed independently.
            let mut depth = vec![1usize; n];
            for i in 0..n {
                for &j in &dag.deps[i] {
                    depth[i] = depth[i].max(depth[j] + 1);
                }
            }
            let want = depth.iter().copied().max().unwrap_or(1);
            if cp != want {
                return Err(format!("critical path {cp} != independent depth {want}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_version_monotonicity() {
    check(
        "writes produce strictly increasing versions",
        &Config::default(),
        |rng| (rng.below(20) + 1, rng.below(5) + 1),
        |&(writes, readers)| {
            let mut reg = DataRegistry::new();
            let key = reg.new_literal(8, NodeId(0));
            let mut last = key.version;
            let mut next_task = 100u64;
            for _ in 0..writes {
                for _ in 0..readers {
                    next_task += 1;
                    reg.record_read(key.data, TaskId(next_task));
                }
                next_task += 1;
                let (new_key, _, war) = reg.record_write(key.data, TaskId(next_task));
                if new_key.version != last + 1 {
                    return Err(format!(
                        "version jumped {last} -> {}",
                        new_key.version
                    ));
                }
                if war.len() != readers as usize {
                    return Err(format!(
                        "WAR edges {} != readers {readers}",
                        war.len()
                    ));
                }
                last = new_key.version;
            }
            Ok(())
        },
    );
}

/// Multi-node value-lifecycle property: random reduction trees on 1-3
/// emulated nodes with the memory plane, asynchronous transfers, and the
/// version GC all enabled — half the cases with the warm tier on, half
/// with file-backed staging (`warm_budget` 0). Consumers race mover
/// threads for every cross-node input (claim-mid-transfer), stealing
/// moves tasks away from the prefetched node, and the GC reclaims each
/// intermediate as its last reader finishes — the sum must stay exact,
/// the claim path must never run the codec synchronously, no dead bytes
/// may remain, and warm blob bytes must drain to zero at quiescence
/// alongside `transfer_states`.
#[test]
fn prop_multi_node_transfers_and_gc_preserve_results() {
    check(
        "multi-node reduction trees with async transfers + gc",
        &Config {
            cases: 8,
            seed: 0xBEEF,
        },
        |rng| {
            let n = 2 + rng.below_usize(24);
            let values: Vec<f64> = (0..n).map(|_| rng.below(1000) as f64).collect();
            let nodes = 1 + rng.below(3) as u32;
            let wpn = 1 + rng.below(2) as u32;
            let policy = ["fifo", "locality"][rng.below_usize(2)];
            let warm = rng.below(2) == 0;
            (values, nodes, wpn, policy, warm)
        },
        |(values, nodes, wpn, policy, warm)| {
            let rt = CompssRuntime::start(
                RuntimeConfig::local(*wpn)
                    .with_nodes(*nodes, *wpn)
                    .with_scheduler(policy)
                    .with_memory_budget(256 << 20)
                    .with_warm_budget(if *warm {
                        rcompss::coordinator::runtime::DEFAULT_WARM_BUDGET
                    } else {
                        0
                    })
                    .with_transfer_threads(1)
                    .with_gc(true),
            )
            .map_err(|e| e.to_string())?;
            let add = rt.register_task(TaskDef::new("add", 2, |a| {
                Ok(vec![RValue::scalar(
                    a[0].as_f64().unwrap() + a[1].as_f64().unwrap(),
                )])
            }));
            let mut layer: Vec<TaskArg> = values.iter().map(|v| TaskArg::from(*v)).collect();
            while layer.len() > 1 {
                let mut next = Vec::new();
                let mut it = layer.into_iter();
                while let Some(a) = it.next() {
                    match it.next() {
                        Some(b) => {
                            let r = rt.submit(&add, &[a, b]).map_err(|e| e.to_string())?;
                            next.push(TaskArg::from(r));
                        }
                        None => next.push(a),
                    }
                }
                layer = next;
            }
            let total = match layer.pop().unwrap() {
                TaskArg::Future(r) => rt
                    .wait_on(&r)
                    .map_err(|e| e.to_string())?
                    .as_f64()
                    .unwrap(),
                TaskArg::Value(v) => v.as_f64().unwrap(),
            };
            let stats = rt.stop().map_err(|e| e.to_string())?;
            let want: f64 = values.iter().sum();
            if (total - want).abs() > 1e-9 {
                return Err(format!("sum {total} != {want}"));
            }
            if chaos_active() {
                // Exactness above is the chaos contract; the quiescence
                // counters below assume failure-free transfers.
                return Ok(());
            }
            if stats.sync_transfer_decodes != 0 {
                return Err(format!(
                    "claim path ran the codec {} time(s) with transfers on",
                    stats.sync_transfer_decodes
                ));
            }
            if stats.transfers_failed != 0 {
                return Err(format!("{} transfer(s) failed", stats.transfers_failed));
            }
            if stats.dead_version_bytes != 0 {
                return Err(format!(
                    "{} dead bytes survived the GC",
                    stats.dead_version_bytes
                ));
            }
            // The GC purges a collected version's transfer-board entries:
            // at quiescence only uncollected versions (here: the pinned
            // final sum) may keep any, so the map must have drained.
            if stats.transfer_states > 2 {
                return Err(format!(
                    "{} transfer-state entries survived quiescence (requested {})",
                    stats.transfer_states, stats.transfers_requested
                ));
            }
            // The GC drains all three tiers: every transferred (and thus
            // warm-filled) version was consumed and collected, so no blob
            // bytes may survive quiescence.
            if stats.warm_resident_bytes != 0 {
                return Err(format!(
                    "{} warm blob bytes survived quiescence ({} fills)",
                    stats.warm_resident_bytes, stats.warm_fills
                ));
            }
            if !*warm && stats.warm_fills + stats.warm_hits != 0 {
                return Err(format!(
                    "warm tier off but saw traffic: {} fills, {} hits",
                    stats.warm_fills, stats.warm_hits
                ));
            }
            Ok(())
        },
    );
}

/// One frontier event of a random DAG replay: a push with random locality
/// metadata, or a pop by a worker on a random node.
#[derive(Debug, Clone)]
enum FrontierOp {
    Push { inputs: Vec<(u64, Vec<NodeId>)> },
    Pop { node: NodeId },
}

/// Placement-equivalence property: for the same ready-frontier sequence
/// (same DAG, same seed), the live dispatch fabric (`ShardedReady`) and
/// the simulator's router (`RoutedReady`) — both driving the same
/// `PlacementModel` type — make *identical* placement decisions and hand
/// out *identical* tasks. This is what makes simulated placements a
/// faithful stand-in for live ones. The `adaptive` model is exercised
/// warm, both sides reading one shared feedback sink: identical
/// observations must give identical verdicts. Half the cases replay the
/// warm tier's byte signal: once a version's blob is built, the locality
/// snapshot carries its *real serialized size* instead of the payload
/// estimate (`VersionTable::update_bytes`) — equivalence must hold
/// whichever source filled the byte column, since both fabrics route on
/// the same snapshot.
#[test]
fn prop_live_sharded_routing_equals_sim_placement() {
    check(
        "ShardedReady routing == RoutedReady placement",
        &Config::default(),
        |rng| {
            let nodes = 1 + rng.below(4) as u32;
            let policy = ["fifo", "lifo", "locality"][rng.below_usize(3)];
            let model = ["bytes", "cost", "roundrobin", "adaptive"][rng.below_usize(4)];
            // Warm-tier byte signal: serialized sizes instead of payload
            // estimates (a deterministic encode-overhead transform).
            let warm_sizes = rng.below(2) == 0;
            let n_ops = 5 + rng.below_usize(60);
            let mut ops = Vec::with_capacity(n_ops);
            for _ in 0..n_ops {
                if rng.below(3) == 0 {
                    ops.push(FrontierOp::Pop {
                        node: NodeId(rng.below(nodes as u64) as u32),
                    });
                } else {
                    let n_inputs = rng.below_usize(4);
                    let inputs = (0..n_inputs)
                        .map(|_| {
                            let payload = rng.below(10_000);
                            let bytes = if warm_sizes {
                                payload + payload / 8 + 32
                            } else {
                                payload
                            };
                            let n_locs = rng.below_usize(3);
                            let locs = (0..n_locs)
                                .map(|_| NodeId(rng.below(nodes as u64) as u32))
                                .collect();
                            (bytes, locs)
                        })
                        .collect();
                    ops.push(FrontierOp::Push { inputs });
                }
            }
            (nodes, policy, model, ops)
        },
        |(nodes, policy, model, ops)| {
            // Two independent model instances — except `adaptive`, whose
            // warm path is only comparable under identical observations:
            // both sides share ONE feedback sink (pre-seeded past the warm
            // gate with a skewed bandwidth profile), mirroring a live run
            // and a simulation that learned the same signals.
            let (live_model, sim_model): (Arc<dyn PlacementModel>, Arc<dyn PlacementModel>) =
                if *model == "adaptive" {
                    let stats = Arc::new(FeedbackStats::new());
                    stats.record_transfer(NodeId(0), 4_096, 1.0);
                    stats.record_transfer(NodeId(1), 1 << 20, 0.5);
                    stats.record_transfer(NodeId(0), 2_048, 1.0);
                    stats.record_task("t", 0.002);
                    let live: Arc<dyn PlacementModel> =
                        Arc::new(AdaptivePlacement::with_stats(Arc::clone(&stats)));
                    let sim: Arc<dyn PlacementModel> =
                        Arc::new(AdaptivePlacement::with_stats(stats));
                    (live, sim)
                } else {
                    (
                        placement_by_name(model).unwrap(),
                        placement_by_name(model).unwrap(),
                    )
                };
            let live =
                ShardedReady::new(policy, *nodes, live_model, None).expect("live fabric");
            let mut sim = RoutedReady::new(policy, *nodes, sim_model).expect("sim router");
            let mut next_id = 0u64;
            for (i, op) in ops.iter().enumerate() {
                match op {
                    FrontierOp::Push { inputs } => {
                        next_id += 1;
                        let mk = || ReadyTask {
                            id: TaskId(next_id),
                            inputs: inputs.clone(),
                            type_name: "t".into(),
                        };
                        let l = live.push(mk());
                        let s = sim.push(mk());
                        if l != s {
                            return Err(format!(
                                "op {i}: live routed task {next_id} to {l}, sim to {s} \
                                 [{model}/{policy}, {nodes} nodes]"
                            ));
                        }
                    }
                    FrontierOp::Pop { node } => {
                        // Never pop an empty fabric: ShardedReady::pop
                        // parks (it is the worker-side blocking API).
                        if live.queue_len() == 0 {
                            continue;
                        }
                        let l = live.pop(*node);
                        let s = sim.pop_for(*node);
                        if l != s {
                            return Err(format!(
                                "op {i}: pop on node {} returned {l:?} live vs {s:?} sim",
                                node.0
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Live-runtime property: random reduction trees over addition always
/// compute the exact total, under any scheduler, any codec, any worker
/// count.
#[test]
fn prop_live_reduction_trees_are_exact() {
    check(
        "live reduction trees sum correctly",
        &Config {
            cases: 10,
            seed: 0xFEED,
        },
        |rng| {
            let n = 2 + rng.below_usize(24);
            let values: Vec<f64> = (0..n).map(|_| rng.below(1000) as f64).collect();
            let workers = 1 + rng.below(4) as u32;
            let policy = ["fifo", "lifo", "locality"][rng.below_usize(3)];
            let codec = ["rmvl", "qs", "rawbin"][rng.below_usize(3)];
            (values, workers, policy, codec)
        },
        |(values, workers, policy, codec)| {
            // File plane pinned (budget 0, GC off): this property is the
            // codec soak — the default memory plane would bypass it.
            let rt = CompssRuntime::start(
                RuntimeConfig::local(*workers)
                    .with_scheduler(policy)
                    .with_codec(codec)
                    .with_memory_budget(0)
                    .with_gc(false),
            )
            .map_err(|e| e.to_string())?;
            let add = rt.register_task(TaskDef::new("add", 2, |a| {
                Ok(vec![RValue::scalar(
                    a[0].as_f64().unwrap() + a[1].as_f64().unwrap(),
                )])
            }));
            // Pairwise reduction tree.
            let mut layer: Vec<TaskArg> =
                values.iter().map(|v| TaskArg::from(*v)).collect();
            while layer.len() > 1 {
                let mut next = Vec::new();
                let mut it = layer.into_iter();
                while let Some(a) = it.next() {
                    match it.next() {
                        Some(b) => {
                            let r = rt.submit(&add, &[a, b]).map_err(|e| e.to_string())?;
                            next.push(TaskArg::from(r));
                        }
                        None => next.push(a),
                    }
                }
                layer = next;
            }
            let total = match layer.pop().unwrap() {
                TaskArg::Future(r) => rt
                    .wait_on(&r)
                    .map_err(|e| e.to_string())?
                    .as_f64()
                    .unwrap(),
                TaskArg::Value(v) => v.as_f64().unwrap(),
            };
            rt.stop().map_err(|e| e.to_string())?;
            let want: f64 = values.iter().sum();
            if (total - want).abs() > 1e-9 {
                return Err(format!("sum {total} != {want}"));
            }
            Ok(())
        },
    );
}
