//! Window-compiler acceptance suite: `--compile window` must be a pure
//! performance decision (results byte-identical to `--compile off` for
//! every app, router, and fuzzed schedule) while its four passes — dead-
//! task culling, AOT lifetimes with hot-buffer aliasing, sub-threshold
//! chain fusion, and whole-window placement — observably fire on plans
//! that expose supersession.
//!
//! App plans never overwrite a datum (every output is a fresh future), so
//! cull/fusion/alias are exercised here through synthetic plans with
//! `Direction::Out` / `Direction::InOut` arguments; the app matrix pins
//! the equivalence side. Both compile modes are pinned explicitly in
//! every runtime built here, so the CI `RCOMPSS_COMPILE` env dimension
//! can never flip a baseline under the comparison.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use rcompss::api::{CompssRuntime, RuntimeConfig, TaskDef};
use rcompss::apps::backend::Backend;
use rcompss::apps::kmeans::{self, KmeansConfig};
use rcompss::apps::knn::{self, KnnConfig};
use rcompss::apps::linreg::{self, LinregConfig};
use rcompss::apps::{LiveSink, Shapes};
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::coordinator::access::Direction;
use rcompss::coordinator::fault::ChaosSpec;
use rcompss::sim::plans::knn_plan;
use rcompss::sim::{CostModel, SimEngine};
use rcompss::value::RValue;

fn chaos_active() -> bool {
    std::env::var("RCOMPSS_CHAOS").map_or(false, |v| {
        rcompss::coordinator::fault::ChaosSpec::parse(&v)
            .map_or(false, |s| s.is_active())
    })
}

fn tiny_shapes() -> Shapes {
    Shapes {
        knn_train_n: 128,
        knn_test_block: 32,
        knn_d: 8,
        knn_k: 3,
        knn_classes: 3,
        km_frag_n: 96,
        km_d: 4,
        km_k: 3,
        lr_frag_n: 64,
        lr_p: 8,
        lr_pred_block: 32,
        ..Shapes::default()
    }
}

// ---------------------------------------------------------------------------
// Equivalence: every app, every router, compiler on vs off.
// ---------------------------------------------------------------------------

#[test]
fn knn_is_byte_identical_across_routers_and_compile_modes() {
    let mut cfg = KnnConfig::small(5);
    cfg.shapes = tiny_shapes();
    cfg.train_fragments = 4;
    cfg.test_blocks = 2;
    let mut reference: Option<Vec<i32>> = None;
    for compile in ["off", "window"] {
        for router in ["bytes", "cost", "roundrobin", "adaptive"] {
            let rt = CompssRuntime::start(
                RuntimeConfig::local(2)
                    .with_nodes(2, 2)
                    .with_router(router)
                    .with_compile(compile),
            )
            .unwrap();
            let mut sink = LiveSink::new(
                &rt,
                rcompss::apps::backend::knn_task_defs(cfg.shapes, Backend::Native),
            );
            let plan = knn::plan_knn(&mut sink, &cfg).unwrap();
            let classes = sink.fetch(plan.classes[0]).unwrap();
            let got = classes.as_int().unwrap().to_vec();
            let stats = rt.stop().unwrap();
            if compile == "window" {
                assert!(
                    stats.windows_flushed > 0,
                    "compiler armed but no window flushed: {stats:?}"
                );
                if !chaos_active() {
                    // Satellite invariants survive compilation: the board
                    // identity and a drained version table at quiescence.
                    assert_eq!(
                        stats.transfers_prefetched
                            + stats.transfers_waited
                            + stats.transfers_dropped
                            + stats.transfers_failed,
                        stats.transfers_requested,
                        "router {router}: {stats:?}"
                    );
                    assert_eq!(stats.dead_version_bytes, 0, "router {router}: {stats:?}");
                }
            }
            match &reference {
                None => reference = Some(got),
                Some(want) => assert_eq!(
                    &got, want,
                    "compile {compile} router {router} changed results"
                ),
            }
        }
    }
}

#[test]
fn kmeans_and_linreg_are_byte_identical_with_compiler_armed() {
    let shapes = tiny_shapes();
    // K-means, fixed iterations so both runs build the same DAG.
    let mut kcfg = KmeansConfig::small(11);
    kcfg.shapes = shapes;
    kcfg.fragments = 3;
    kcfg.iterations = 3;
    kcfg.tol = None;
    let kmeans_run = |compile: &str| {
        let rt = CompssRuntime::start(
            RuntimeConfig::local(3).with_compile(compile),
        )
        .unwrap();
        let res = kmeans::run_kmeans(&rt, &kcfg, Backend::Native).unwrap();
        rt.stop().unwrap();
        res.centroids
    };
    let off = kmeans_run("off");
    let on = kmeans_run("window");
    assert!(off.all_equal(&on, 0.0), "compiler changed the k-means centroids");

    let mut lcfg = LinregConfig::small(2);
    lcfg.shapes = shapes;
    lcfg.fragments = 4;
    lcfg.pred_blocks = 2;
    let linreg_run = |compile: &str| {
        let rt = CompssRuntime::start(
            RuntimeConfig::local(3).with_compile(compile),
        )
        .unwrap();
        let res = linreg::run_linreg(&rt, &lcfg, Backend::Native).unwrap();
        rt.stop().unwrap();
        res
    };
    let off = linreg_run("off");
    let on = linreg_run("window");
    assert!(off.beta.all_equal(&on.beta, 0.0), "compiler changed the linreg fit");
    assert_eq!(off.r2.to_bits(), on.r2.to_bits(), "compiler changed r2");
}

// ---------------------------------------------------------------------------
// The passes, observably: cull / fusion / alias / whole-window placement.
// ---------------------------------------------------------------------------

#[test]
fn compiler_culls_dead_producer_without_executing_it() {
    // t1 produces d#v1; t2 OUT-writes d (v1 superseded, never read). With
    // the window still buffered at the first sync, the compiler retires
    // t1 — its body must never run — and t2 alone produces the result.
    // Chaos pinned off: the exact counters below assume no retries.
    let rt = CompssRuntime::start(
        RuntimeConfig::local(2)
            .with_compile("window")
            .with_chaos(ChaosSpec::default()),
    )
    .unwrap();
    let executed = Arc::new(AtomicBool::new(false));
    let mk = {
        let executed = Arc::clone(&executed);
        rt.register_task(TaskDef::new("mk", 0, move |_| {
            executed.store(true, Ordering::Release);
            Ok(vec![RValue::scalar(1.0)])
        }))
    };
    let ow = rt.register_task(
        TaskDef::new("ow", 1, |_| Ok(vec![RValue::scalar(2.0)]))
            .with_outputs(0)
            .with_directions(vec![Direction::Out]),
    );
    let v1 = rt.submit(&mk, &[]).unwrap();
    let outs = rt.submit_multi(&ow, &[v1.into()]).unwrap();
    assert_eq!(outs.len(), 1);
    let got = rt.wait_on(&outs[0]).unwrap();
    assert_eq!(got.as_f64(), Some(2.0));
    let stats = rt.stop().unwrap();
    assert_eq!(stats.window_culled, 1, "{stats:?}");
    assert!(
        !executed.load(Ordering::Acquire),
        "culled producer must never execute"
    );
}

#[test]
fn wait_on_of_elided_version_names_the_compiler() {
    // Fetching a version the compiler already retired is a programming
    // error (the overwrite was submitted before the fetch); the message
    // must blame the elision, not the version GC.
    let rt = CompssRuntime::start(
        RuntimeConfig::local(2)
            .with_compile("window")
            .with_chaos(ChaosSpec::default()),
    )
    .unwrap();
    let mk = rt.register_task(TaskDef::new("mk", 0, |_| Ok(vec![RValue::scalar(1.0)])));
    let ow = rt.register_task(
        TaskDef::new("ow", 1, |_| Ok(vec![RValue::scalar(2.0)]))
            .with_outputs(0)
            .with_directions(vec![Direction::Out]),
    );
    let v1 = rt.submit(&mk, &[]).unwrap();
    let outs = rt.submit_multi(&ow, &[v1.into()]).unwrap();
    assert_eq!(rt.wait_on(&outs[0]).unwrap().as_f64(), Some(2.0));
    let err = rt.wait_on(&v1).unwrap_err().to_string();
    assert!(
        err.contains("elided by the window compiler"),
        "wrong attribution: {err}"
    );
    rt.stop().unwrap();
}

#[test]
fn compiler_fuses_sub_threshold_inout_chain() {
    // init → bump → bump → bump over one datum: each intermediate version
    // is superseded with exactly one reader, so the whole chain collapses
    // into a single dispatch unit (three fusion links) — and the result
    // is exactly what four separate executions produce. Chaos pinned
    // off: the exact fusion/task counters assume no retries.
    let rt = CompssRuntime::start(
        RuntimeConfig::local(2)
            .with_compile("window")
            .with_chaos(ChaosSpec::default()),
    )
    .unwrap();
    let init = rt.register_task(TaskDef::new("init", 0, |_| Ok(vec![RValue::scalar(0.0)])));
    let bump = rt.register_task(
        TaskDef::new("bump", 1, |a| {
            Ok(vec![RValue::scalar(a[0].as_f64().unwrap() + 1.0)])
        })
        .with_outputs(0)
        .with_directions(vec![Direction::InOut]),
    );
    let mut latest = rt.submit(&init, &[]).unwrap();
    for _ in 0..3 {
        latest = rt.submit_multi(&bump, &[latest.into()]).unwrap()[0];
    }
    let v = rt.wait_on(&latest).unwrap();
    assert_eq!(v.as_f64(), Some(3.0));
    let stats = rt.stop().unwrap();
    assert_eq!(stats.window_fused, 3, "{stats:?}");
    assert_eq!(stats.tasks_done, 4, "fused members still execute: {stats:?}");
    assert_eq!(stats.window_culled, 0, "{stats:?}");
}

#[test]
fn aot_lifetimes_alias_hot_buffers_without_extra_peak() {
    // A 1.6 MB fragment read by two in-window consumers (two readers
    // defeat fusion; the size defeats the fusion byte gate anyway) and
    // then superseded by an OUT write: the compiler proves the last
    // reader ends the fragment's lifetime and frees it *before* that
    // reader's equally-sized output is published, so the hot tier's peak
    // stays at ~one fragment where the uncompiled run holds two. One
    // worker makes the release order deterministic.
    const N: usize = 200_000; // 1.6 MB of f64 — above the fusion byte gate
    let run = |compile: &str| {
        let rt = CompssRuntime::start(
            RuntimeConfig::local(1)
                .with_compile(compile)
                .with_chaos(ChaosSpec::default()),
        )
        .unwrap();
        let mk = rt.register_task(TaskDef::new("mk", 0, |_| {
            Ok(vec![RValue::Real(vec![1.0; N])])
        }));
        let stage = rt.register_task(TaskDef::new("stage", 1, |a| {
            Ok(vec![RValue::scalar(a[0].as_real().unwrap().iter().sum())])
        }));
        let finish = rt.register_task(TaskDef::new("finish", 2, |a| {
            let frag = a[0].as_real().unwrap();
            let scale = a[1].as_f64().unwrap() / frag.len() as f64;
            Ok(vec![RValue::Real(frag.iter().map(|x| x * scale).collect())])
        }));
        let ow = rt.register_task(
            TaskDef::new("ow", 1, |_| Ok(vec![RValue::scalar(0.0)]))
                .with_outputs(0)
                .with_directions(vec![Direction::Out]),
        );
        let frag = rt.submit(&mk, &[]).unwrap();
        let sum = rt.submit(&stage, &[frag.into()]).unwrap();
        let scaled = rt.submit(&finish, &[frag.into(), sum.into()]).unwrap();
        rt.submit_multi(&ow, &[frag.into()]).unwrap();
        let v = rt.wait_on(&scaled).unwrap();
        assert_eq!(v.as_real().unwrap()[0], 1.0, "compile {compile}");
        rt.stop().unwrap()
    };
    let off = run("off");
    let on = run("window");
    assert!(on.aot_frees >= 1, "lifetime pass never freed: {on:?}");
    assert!(on.alias_reuses >= 1, "freed pool never reused: {on:?}");
    assert_eq!(on.window_fused, 0, "two readers must defeat fusion: {on:?}");
    // The uncompiled run holds the dead fragment across the publish of
    // its equally-sized successor; the compiled run does not.
    let frag_bytes = (N * 8) as u64;
    assert!(
        off.hot_peak_bytes >= 2 * frag_bytes,
        "off-run peak should hold two fragments: {off:?}"
    );
    assert!(
        on.hot_peak_bytes < 2 * frag_bytes,
        "aliasing must cap the peak below two fragments: {on:?}"
    );
    assert!(on.hot_peak_bytes <= off.hot_peak_bytes, "{on:?} vs {off:?}");
}

#[test]
fn whole_window_placement_issues_one_verdict_per_window() {
    // Eight independent producers: greedy dispatch consults the model
    // once per task, a compiled window exactly once in total.
    let run = |compile: &str| {
        let rt = CompssRuntime::start(
            RuntimeConfig::local(1)
                .with_nodes(2, 1)
                .with_compile(compile)
                .with_chaos(ChaosSpec::default()),
        )
        .unwrap();
        let mk = rt.register_task(TaskDef::new("mk", 1, |a| {
            Ok(vec![RValue::scalar(2.0 * a[0].as_f64().unwrap())])
        }));
        let outs: Vec<_> = (0..8)
            .map(|i| rt.submit(&mk, &[(i as f64).into()]).unwrap())
            .collect();
        rt.barrier().unwrap();
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(rt.wait_on(o).unwrap().as_f64(), Some(2.0 * i as f64));
        }
        rt.stop().unwrap()
    };
    let off = run("off");
    let on = run("window");
    assert_eq!(off.placement_verdicts, 8, "one greedy verdict per task: {off:?}");
    assert_eq!(on.placement_verdicts, 1, "one verdict per window: {on:?}");
    assert_eq!(on.windows_flushed, 1, "{on:?}");
    assert_eq!(on.tasks_done, 8, "{on:?}");
}

// ---------------------------------------------------------------------------
// Fuzzed schedules with the compiler armed: live and simulated planes.
// ---------------------------------------------------------------------------

#[test]
fn fuzzed_live_schedule_with_compiler_armed_keeps_results_exact() {
    // The live yield-point harness on top of a compiled 4-node k-means:
    // widened hazard windows must not let the compiler's AOT death lists
    // or fused claims race the GC/transfer planes. Chaos pinned off so
    // the ambient CI matrix cannot change what this seed means.
    let mut cfg = KmeansConfig::small(11);
    cfg.shapes = tiny_shapes();
    cfg.fragments = 4;
    cfg.iterations = 3;
    let clean = {
        let rt = CompssRuntime::start(
            RuntimeConfig::local(2)
                .with_nodes(4, 2)
                .with_router("cost")
                .with_compile("off")
                .with_chaos(ChaosSpec::default()),
        )
        .unwrap();
        let res = kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
        rt.stop().unwrap();
        res.centroids
    };
    let rt = CompssRuntime::start(
        RuntimeConfig::local(2)
            .with_nodes(4, 2)
            .with_router("cost")
            .with_compile("window")
            .with_sched_fuzz(7)
            .with_chaos(ChaosSpec::default()),
    )
    .unwrap();
    let res = kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
    let stats = rt.stop().unwrap();
    assert!(
        clean.all_equal(&res.centroids, 0.0),
        "compiled + fuzzed schedule changed the result"
    );
    assert!(stats.windows_flushed > 0, "{stats:?}");
    assert!(stats.sched_fuzz_perturbations > 0, "{stats:?}");
    assert_eq!(stats.tasks_failed, 0, "{stats:?}");
    assert_eq!(stats.dead_version_bytes, 0, "{stats:?}");
    assert_eq!(
        stats.transfers_prefetched
            + stats.transfers_waited
            + stats.transfers_dropped
            + stats.transfers_failed,
        stats.transfers_requested,
        "{stats:?}"
    );
}

fn seeds(lane: u64, n: u64) -> Vec<u64> {
    let base = std::env::var("RCOMPSS_FUZZ_SEED_BASE")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or(1);
    (0..n)
        .map(|i| base.wrapping_mul(1000).wrapping_add(lane * 100 + i))
        .collect()
}

fn cluster(nodes: u32, wpn: u32) -> ClusterSpec {
    ClusterSpec::new(MachineProfile::shaheen3(), nodes).with_workers_per_node(wpn)
}

#[test]
fn sim_fuzz_sweep_with_compiler_matches_uncompiled_digests() {
    // 64 seeds through the simulated twin, compiler armed, against the
    // same 64 seeds uncompiled: per-seed the final data-plane digest and
    // completed-task count must be byte-identical (app plans never
    // supersede, so the compiler may only re-batch placement — never
    // change what is computed), and the placement-verdict count must
    // collapse from one-per-task to one-per-window.
    let s = seeds(5, 64);
    let compiled = SimEngine::new(cluster(4, 2), CostModel::default())
        .with_router("cost")
        .with_compile(true)
        .fuzz_sweep(&s, || knn_plan(6, 3, 1), "knn-compiled")
        .unwrap();
    let plain = SimEngine::new(cluster(4, 2), CostModel::default())
        .with_router("cost")
        .with_compile(false)
        .fuzz_sweep(&s, || knn_plan(6, 3, 1), "knn-plain")
        .unwrap();
    assert_eq!(compiled.len(), 64);
    for (c, p) in compiled.iter().zip(&plain) {
        assert_eq!(c.tasks_done, p.tasks_done, "seed {:?}", c.fuzz_seed);
        assert_eq!(
            c.result_digest, p.result_digest,
            "seed {:?}: compilation changed the data plane",
            c.fuzz_seed
        );
        assert!(
            c.placement_verdicts * 8 <= p.placement_verdicts,
            "seed {:?}: verdicts did not collapse ({} vs {})",
            c.fuzz_seed,
            c.placement_verdicts,
            p.placement_verdicts
        );
        assert_eq!(c.window_culled, 0, "app plans never supersede");
        assert_eq!(c.window_fused, 0, "app plans never supersede");
    }
}

#[test]
fn sim_compiled_run_reports_window_counters() {
    // Deterministic single run: the compiled report carries the verdict
    // collapse; the plan drains to the same task count either way.
    let run = |compile: bool| {
        SimEngine::new(cluster(3, 2), CostModel::default())
            .with_compile(compile)
            .run(knn_plan(8, 4, 1).unwrap(), "knn-compile")
            .unwrap()
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(on.tasks_done, off.tasks_done);
    assert_eq!(on.result_digest, off.result_digest);
    assert!(
        on.placement_verdicts < off.placement_verdicts,
        "{} !< {}",
        on.placement_verdicts,
        off.placement_verdicts
    );
}
