//! Loopback-TCP transport invariance: the same suites that pin the
//! in-process shipping plane, re-run over real sockets. With
//! `--transport tcp` and no `--listen`, the coordinator self-hosts its
//! worker peers as threads connected through 127.0.0.1 — so everything
//! above `Transport::fetch` (placement, feedback, GC, transfer
//! accounting) runs unmodified while the staged bytes cross a real wire.

use std::sync::Arc;

use rcompss::api::{CompssRuntime, RuntimeConfig};
use rcompss::apps::backend::Backend;
use rcompss::apps::kmeans::{self, KmeansConfig};
use rcompss::apps::knn::{self, KnnConfig};
use rcompss::apps::linreg::{self, LinregConfig};
use rcompss::apps::{LiveSink, Shapes};

/// See `integration_runtime.rs`: under the CI chaos matrix the strict
/// performance-counter assertions are meaningless; results stay pinned.
fn chaos_active() -> bool {
    std::env::var("RCOMPSS_CHAOS").map_or(false, |v| {
        rcompss::coordinator::fault::ChaosSpec::parse(&v)
            .map_or(false, |s| s.is_active())
    })
}

fn tiny_shapes() -> Shapes {
    Shapes {
        knn_train_n: 128,
        knn_test_block: 32,
        knn_d: 8,
        knn_k: 3,
        knn_classes: 3,
        km_frag_n: 96,
        km_d: 4,
        km_k: 3,
        lr_frag_n: 64,
        lr_p: 8,
        lr_pred_block: 32,
        ..Shapes::default()
    }
}

#[test]
fn tcp_two_node_claims_never_run_codec_synchronously() {
    // Loopback-TCP twin of the in-process 2-node acceptance test: claims
    // must never run the codec synchronously, every transfer request must
    // be accounted for, and results must match the single-node run.
    let mut cfg = KnnConfig::small(5);
    cfg.shapes = tiny_shapes();
    cfg.train_fragments = 4;
    cfg.test_blocks = 2;
    let run = |nodes: u32| {
        let rt = CompssRuntime::start(
            RuntimeConfig::local(2)
                .with_nodes(nodes, 2)
                .with_memory_budget(256 << 20)
                .with_gc(true)
                .with_transport("tcp"),
        )
        .unwrap();
        let mut sink = LiveSink::new(
            &rt,
            rcompss::apps::backend::knn_task_defs(cfg.shapes, Backend::Native),
        );
        let plan = knn::plan_knn(&mut sink, &cfg).unwrap();
        let classes = sink.fetch(plan.classes[0]).unwrap();
        let got = classes.as_int().unwrap().to_vec();
        let stats = rt.stop().unwrap();
        (got, stats)
    };
    let (single, _) = run(1);
    let (multi, stats) = run(2);
    assert_eq!(single, multi, "node count changed classification over TCP");
    if chaos_active() {
        return;
    }
    assert_eq!(
        stats.sync_transfer_decodes, 0,
        "claim paths must never run the codec for cross-node inputs: {stats:?}"
    );
    assert_eq!(stats.transfers_failed, 0, "{stats:?}");
    assert_eq!(stats.dead_version_bytes, 0, "{stats:?}");
    assert_eq!(
        stats.transfers_prefetched
            + stats.transfers_waited
            + stats.transfers_dropped
            + stats.transfers_failed,
        stats.transfers_requested,
        "transfer accounting is consistent over TCP: {stats:?}"
    );
    assert!(
        stats.transfer_states <= 16,
        "transfer tombstones must not accumulate: {stats:?}"
    );
}

#[test]
fn apps_are_byte_identical_across_transports_and_routers() {
    // The transport is a shipping mechanism, never a semantic one: for the
    // same seed, every app must produce bit-identical floats in-process
    // and over loopback TCP, under every placement model. (Compute runs in
    // coordinator worker threads under both transports; TCP only changes
    // how staged replica bytes move.)
    let shapes = tiny_shapes();
    // Three arms: in-process, TCP with direct shipping (the default), and
    // TCP forced through the coordinator relay (`--p2p off`) — the data
    // path a blob takes must never change a float.
    let arms: [(&str, bool); 3] = [("inproc", true), ("tcp", true), ("tcp", false)];
    for router in ["bytes", "cost", "roundrobin", "adaptive"] {
        let config = |(transport, p2p): (&str, bool)| {
            RuntimeConfig::local(2)
                .with_nodes(2, 2)
                .with_router(router)
                .with_transport(transport)
                .with_p2p(p2p)
        };
        // KNN.
        let knn_run = |arm: (&str, bool)| {
            let rt = CompssRuntime::start(config(arm)).unwrap();
            let mut cfg = KnnConfig::small(5);
            cfg.shapes = shapes;
            cfg.train_fragments = 4;
            cfg.test_blocks = 2;
            let res = knn::run_knn(&rt, &cfg, Backend::Native).unwrap();
            rt.stop().unwrap();
            res
        };
        let ki = knn_run(arms[0]);
        for arm in &arms[1..] {
            let kt = knn_run(*arm);
            assert_eq!(
                ki.accuracy.to_bits(),
                kt.accuracy.to_bits(),
                "router {router}, arm {arm:?}: knn accuracy diverged"
            );
            assert_eq!(ki.total_test_points, kt.total_test_points);
        }
        // K-means.
        let km_run = |arm: (&str, bool)| {
            let rt = CompssRuntime::start(config(arm)).unwrap();
            let mut cfg = KmeansConfig::small(11);
            cfg.shapes = shapes;
            cfg.fragments = 3;
            cfg.iterations = 3;
            cfg.tol = None;
            let res = kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
            rt.stop().unwrap();
            res
        };
        let mi = km_run(arms[0]);
        for arm in &arms[1..] {
            let mt = km_run(*arm);
            assert!(
                mi.centroids.all_equal(&mt.centroids, 0.0),
                "router {router}, arm {arm:?}: k-means centroids diverged"
            );
            assert_eq!(mi.iterations_run, mt.iterations_run);
            assert_eq!(mi.last_shift.to_bits(), mt.last_shift.to_bits());
        }
        // Linreg.
        let lr_run = |arm: (&str, bool)| {
            let rt = CompssRuntime::start(config(arm)).unwrap();
            let mut cfg = LinregConfig::small(2);
            cfg.shapes = shapes;
            cfg.fragments = 4;
            cfg.pred_blocks = 2;
            let res = linreg::run_linreg(&rt, &cfg, Backend::Native).unwrap();
            rt.stop().unwrap();
            res
        };
        let li = lr_run(arms[0]);
        for arm in &arms[1..] {
            let lt = lr_run(*arm);
            assert!(
                li.beta.all_equal(&lt.beta, 0.0),
                "router {router}, arm {arm:?}: linreg beta diverged"
            );
            assert_eq!(li.beta_max_err.to_bits(), lt.beta_max_err.to_bits());
            assert_eq!(li.r2.to_bits(), lt.r2.to_bits());
        }
    }
}

#[test]
fn tcp_warm_fanout_ships_the_blob_with_one_encode_and_zero_file_io() {
    // TCP twin of the warm fan-out acceptance test: a memory-resident
    // version fanned out to a 4-node loopback-TCP fabric costs exactly one
    // encode and zero coordinator-side file I/O — the movers put the warm
    // tier's already-encoded blob on the wire verbatim.
    use rcompss::api::TaskDef;
    use rcompss::value::RValue;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::{Duration, Instant};
    let rt = CompssRuntime::start(
        RuntimeConfig::local(1)
            .with_nodes(4, 1)
            .with_router("roundrobin")
            .with_warm_budget(rcompss::coordinator::runtime::DEFAULT_WARM_BUDGET)
            .with_transport("tcp"),
    )
    .unwrap();
    let mk = rt.register_task(TaskDef::new("mk", 0, |_| {
        Ok(vec![RValue::Real(vec![1.25; 4096])])
    }));
    let gate = Arc::new(AtomicBool::new(false));
    let consume = {
        let gate = Arc::clone(&gate);
        rt.register_task(TaskDef::new("consume", 1, move |a| {
            while !gate.load(Ordering::Acquire) {
                std::thread::yield_now();
            }
            Ok(vec![RValue::scalar(a[0].as_real().unwrap().iter().sum())])
        }))
    };
    let src = rt.submit(&mk, &[]).unwrap();
    let outs: Vec<_> = (0..8)
        .map(|_| rt.submit(&consume, &[src.into()]).unwrap())
        .collect();
    let t0 = Instant::now();
    loop {
        let s = rt.stats();
        if s.transfers_prefetched + s.transfers_waited >= 3 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "fan-out staging never completed: {s:?}"
        );
        std::thread::yield_now();
    }
    gate.store(true, Ordering::Release);
    let mut total = 0.0;
    for o in &outs {
        total += rt.wait_on(o).unwrap().as_f64().unwrap();
    }
    let stats = rt.stop().unwrap();
    assert_eq!(total, 8.0 * 1.25 * 4096.0);
    if !chaos_active() {
        assert_eq!(stats.store_encodes, 1, "{stats:?}");
        assert_eq!(stats.store_file_reads, 0, "{stats:?}");
        assert_eq!(stats.store_file_writes, 0, "{stats:?}");
        assert!(stats.warm_hits >= 1, "fan-out replicas hit warm: {stats:?}");
        assert_eq!(stats.sync_transfer_decodes, 0, "{stats:?}");
    }
}

#[test]
fn tcp_warm_fanout_direct_ships_peer_to_peer() {
    // Direct-shipping twin of the warm fan-out test: with five nodes and
    // the producer pinned to node 1, the blob is seeded to node 1 exactly
    // once (one coordinator Put) and then travels worker-to-worker to
    // nodes 2, 3 and 4 as BlobChunk streams — the coordinator's egress
    // carries one blob plus control frames, never four blobs.
    use rcompss::api::TaskDef;
    use rcompss::value::RValue;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::{Duration, Instant};
    let rt = CompssRuntime::start(
        RuntimeConfig::local(1)
            .with_nodes(5, 1)
            .with_router("roundrobin")
            .with_warm_budget(rcompss::coordinator::runtime::DEFAULT_WARM_BUDGET)
            .with_transport("tcp"),
    )
    .unwrap();
    // The round-robin cursor starts at node 0: burn that slot with a dummy
    // so the producer lands on node 1 — a real worker with a peer listener.
    let dummy = rt.register_task(TaskDef::new("dummy", 0, |_| {
        Ok(vec![RValue::scalar(0.0)])
    }));
    let mk = rt.register_task(TaskDef::new("mk", 0, |_| {
        Ok(vec![RValue::Real(vec![1.25; 4096])])
    }));
    let gate = Arc::new(AtomicBool::new(false));
    let consume = {
        let gate = Arc::clone(&gate);
        rt.register_task(TaskDef::new("consume", 1, move |a| {
            while !gate.load(Ordering::Acquire) {
                std::thread::yield_now();
            }
            Ok(vec![RValue::scalar(a[0].as_real().unwrap().iter().sum())])
        }))
    };
    let pin = rt.submit(&dummy, &[]).unwrap();
    let src = rt.submit(&mk, &[]).unwrap();
    // Consumers round-robin over nodes 2,3,4,0,1,2,3,4: cross-node
    // destinations are {0, 2, 3, 4}, so four transfers stage — three of
    // them to peer-capable workers reachable from the node-1 replica.
    let outs: Vec<_> = (0..8)
        .map(|_| rt.submit(&consume, &[src.into()]).unwrap())
        .collect();
    let t0 = Instant::now();
    loop {
        let s = rt.stats();
        if s.transfers_prefetched + s.transfers_waited >= 4 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "fan-out staging never completed: {s:?}"
        );
        std::thread::yield_now();
    }
    gate.store(true, Ordering::Release);
    let mut total = rt.wait_on(&pin).unwrap().as_f64().unwrap();
    for o in &outs {
        total += rt.wait_on(o).unwrap().as_f64().unwrap();
    }
    let stats = rt.stop().unwrap();
    assert_eq!(total, 8.0 * 1.25 * 4096.0);
    if !chaos_active() {
        assert_eq!(stats.direct_ships, 3, "{stats:?}");
        assert_eq!(stats.relay_ships, 0, "{stats:?}");
        assert_eq!(stats.seed_ships, 1, "{stats:?}");
        assert_eq!(stats.store_encodes, 1, "{stats:?}");
        assert_eq!(stats.store_file_reads, 0, "{stats:?}");
        assert_eq!(stats.store_file_writes, 0, "{stats:?}");
        assert_eq!(stats.sync_transfer_decodes, 0, "{stats:?}");
        // The whole point: blob bytes ride worker-to-worker links, so the
        // coordinator's own egress is one seeded blob plus tiny control
        // frames — well under half the bytes the transfer plane moved.
        assert!(
            stats.coord_egress_bytes < stats.transfer_bytes / 2,
            "direct shipping must keep blob bytes off the coordinator \
             egress: {stats:?}"
        );
    }
}

#[test]
fn tcp_direct_fanout_survives_peer_kill_with_relay_fallback() {
    // Mid-stream peer death maps onto the machinery the relay path already
    // has: a direct ship whose source dies falls back to the coordinator
    // relay inside the same fetch, relay exhaustion escalates to
    // `kill_node_now`, and lineage recovery re-runs whatever dropped. The
    // fan-out must still sum correctly and the transfer board must stay
    // consistent.
    use rcompss::api::TaskDef;
    use rcompss::value::RValue;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::{Duration, Instant};
    let rt = CompssRuntime::start(
        RuntimeConfig::local(1)
            .with_nodes(5, 1)
            .with_router("roundrobin")
            .with_warm_budget(rcompss::coordinator::runtime::DEFAULT_WARM_BUDGET)
            .with_transport("tcp"),
    )
    .unwrap();
    let dummy = rt.register_task(TaskDef::new("dummy", 0, |_| {
        Ok(vec![RValue::scalar(0.0)])
    }));
    let mk = rt.register_task(TaskDef::new("mk", 0, |_| {
        Ok(vec![RValue::Real(vec![1.25; 4096])])
    }));
    let gate = Arc::new(AtomicBool::new(false));
    let consume = {
        let gate = Arc::clone(&gate);
        rt.register_task(TaskDef::new("consume", 1, move |a| {
            while !gate.load(Ordering::Acquire) {
                std::thread::yield_now();
            }
            Ok(vec![RValue::scalar(a[0].as_real().unwrap().iter().sum())])
        }))
    };
    let pin = rt.submit(&dummy, &[]).unwrap();
    let src = rt.submit(&mk, &[]).unwrap();
    let outs: Vec<_> = (0..8)
        .map(|_| rt.submit(&consume, &[src.into()]).unwrap())
        .collect();
    // Wait until the fan-out is in flight, then kill node 1 — the seeded
    // direct-ship source. In-flight and future direct attempts toward it
    // fail and relay; tasks placed on it re-run through lineage recovery.
    let t0 = Instant::now();
    loop {
        let s = rt.stats();
        if s.transfers_prefetched + s.transfers_waited >= 1 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "fan-out never started staging: {s:?}"
        );
        std::thread::yield_now();
    }
    rt.kill_node(1);
    gate.store(true, Ordering::Release);
    let mut total = rt.wait_on(&pin).unwrap().as_f64().unwrap();
    for o in &outs {
        total += rt.wait_on(o).unwrap().as_f64().unwrap();
    }
    let stats = rt.stop().unwrap();
    assert_eq!(
        total,
        8.0 * 1.25 * 4096.0,
        "peer kill changed the fan-out result: {stats:?}"
    );
    assert_eq!(
        stats.transfers_prefetched
            + stats.transfers_waited
            + stats.transfers_dropped
            + stats.transfers_failed,
        stats.transfers_requested,
        "transfer accounting must stay consistent through a peer kill: \
         {stats:?}"
    );
}

#[test]
fn transport_config_is_validated_at_startup() {
    // Unknown transports are rejected, and `--listen` without the TCP
    // transport is a configuration error, not a silent no-op.
    assert!(
        CompssRuntime::start(RuntimeConfig::local(1).with_transport("carrier-pigeon"))
            .is_err()
    );
    assert!(CompssRuntime::start(
        RuntimeConfig::local(1)
            .with_transport("inproc")
            .with_listen("127.0.0.1:0")
    )
    .is_err());
}
