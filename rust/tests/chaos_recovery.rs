//! Node-loss recovery acceptance tests: lineage re-execution after a
//! mid-run kill, checkpoint-driven replay avoidance, elastic kill/join
//! churn, and root-cause failure reporting.
//!
//! The deterministic tests neutralize any ambient `RCOMPSS_CHAOS` plan
//! with an explicit `with_chaos(ChaosSpec::default())` — they stage their
//! own chaos at exact points. The app-level tests install their own
//! seeded node-kill plan and compare against a single-node baseline:
//! losing a node must never change results.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use rcompss::api::{CompssRuntime, RuntimeConfig, TaskDef};
use rcompss::apps::backend::Backend;
use rcompss::apps::kmeans::{self, KmeansConfig};
use rcompss::apps::knn::{self, KnnConfig};
use rcompss::apps::{LiveSink, Shapes};
use rcompss::coordinator::fault::{ChaosSpec, FailureInjector};
use rcompss::coordinator::runtime::RuntimeStats;
use rcompss::value::RValue;

fn tiny_shapes() -> Shapes {
    Shapes {
        knn_train_n: 128,
        knn_test_block: 32,
        knn_d: 8,
        knn_k: 3,
        knn_classes: 3,
        km_frag_n: 96,
        km_d: 4,
        km_k: 3,
        ..Shapes::default()
    }
}

/// Four gated producers — one per node of a 4-node fabric (the gate makes
/// every worker hold one, so each node executes exactly one producer and
/// owns its output as a sole replica). The caller then kills node 3 and a
/// late consumer sums all four fragments. Returns the sum and the stats.
fn gated_fragment_run(checkpoint: &str) -> (f64, RuntimeStats) {
    let config = RuntimeConfig::local(1)
        .with_nodes(4, 1)
        .with_router("roundrobin")
        .with_chaos(ChaosSpec::default())
        .with_checkpoint(checkpoint);
    let rt = CompssRuntime::start(config).unwrap();
    let started = Arc::new(AtomicUsize::new(0));
    let mk = {
        let started = Arc::clone(&started);
        rt.register_task(TaskDef::new("mk_fragment", 1, move |a| {
            // Rendezvous: proceed only once all four producers are running
            // (one per worker). A post-kill re-execution sees the count
            // already past the gate and proceeds immediately.
            started.fetch_add(1, Ordering::AcqRel);
            while started.load(Ordering::Acquire) < 4 {
                std::thread::yield_now();
            }
            let i = a[0].as_f64().unwrap();
            Ok(vec![RValue::Real(vec![i + 0.5; 2048])])
        }))
    };
    let outs: Vec<_> = (0..4)
        .map(|i| rt.submit(&mk, &[(i as f64).into()]).unwrap())
        .collect();
    // Wait until every producer has completed — and, under `--checkpoint
    // cold`, until their sole-replica outputs are actually on disk (the
    // checkpoint write happens just after the completion is counted).
    let t0 = Instant::now();
    loop {
        let s = rt.stats();
        let settled =
            s.tasks_done >= 4 && (checkpoint != "cold" || s.checkpoints_written >= 4);
        if settled {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "producers never settled: {s:?}"
        );
        std::thread::yield_now();
    }
    assert!(rt.kill_node(3), "first kill of a live node must succeed");
    // Consumed only now — no consumer existed before the kill, so no
    // prefetch could have replicated node 3's fragment elsewhere.
    let sum4 = rt.register_task(TaskDef::new("sum4", 4, |a| {
        Ok(vec![RValue::scalar(
            a.iter()
                .map(|v| v.as_real().unwrap().iter().sum::<f64>())
                .sum(),
        )])
    }));
    let total = rt
        .submit(
            &sum4,
            &[outs[0].into(), outs[1].into(), outs[2].into(), outs[3].into()],
        )
        .unwrap();
    let v = rt.wait_on(&total).unwrap().as_f64().unwrap();
    let stats = rt.stop().unwrap();
    (v, stats)
}

#[test]
fn kill_reexecutes_exactly_the_lost_subgraph() {
    let (total, stats) = gated_fragment_run("none");
    assert_eq!(total, 2048.0 * (0.5 + 1.5 + 2.5 + 3.5));
    assert_eq!(stats.nodes_killed, 1, "{stats:?}");
    // Node 3 held exactly one sole-replica fragment: lineage recovery must
    // re-run its producer and nothing else.
    assert_eq!(stats.lineage_resubmissions, 1, "{stats:?}");
}

#[test]
fn checkpoint_cold_strictly_lowers_resubmissions() {
    let (baseline_total, baseline) = gated_fragment_run("none");
    let (total, stats) = gated_fragment_run("cold");
    assert_eq!(total, baseline_total, "checkpointing changed the result");
    assert_eq!(baseline.lineage_resubmissions, 1, "{baseline:?}");
    // Every sole-replica fragment was proactively published through the
    // cold tier, so the kill loses nothing: the lost node's fragment is
    // re-read from its checkpoint file instead of re-derived.
    assert!(stats.checkpoints_written >= 4, "{stats:?}");
    assert!(stats.checkpoint_bytes > 0, "{stats:?}");
    assert!(
        stats.lineage_resubmissions < baseline.lineage_resubmissions,
        "checkpointing must strictly lower replay: {} vs {}",
        stats.lineage_resubmissions,
        baseline.lineage_resubmissions
    );
}

#[test]
fn knn_losing_a_node_mid_run_matches_single_node_results() {
    let mut cfg = KnnConfig::small(5);
    cfg.shapes = tiny_shapes();
    cfg.train_fragments = 4;
    cfg.test_blocks = 2;
    let run = |config: RuntimeConfig| {
        let rt = CompssRuntime::start(config).unwrap();
        let mut sink = LiveSink::new(
            &rt,
            rcompss::apps::backend::knn_task_defs(cfg.shapes, Backend::Native),
        );
        let plan = knn::plan_knn(&mut sink, &cfg).unwrap();
        let classes = sink.fetch(plan.classes[0]).unwrap();
        let got = classes.as_int().unwrap().to_vec();
        let stats = rt.stop().unwrap();
        (got, stats)
    };
    let (clean, _) = run(RuntimeConfig::local(2).with_chaos(ChaosSpec::default()));
    // Seeded node-kill: node 3 dies after a deterministic number of task
    // completions (mid-run for this DAG of 22 tasks).
    let chaos = ChaosSpec::parse("node-kill,seed:11").unwrap();
    let (survivor, stats) = run(
        RuntimeConfig::local(2)
            .with_nodes(4, 2)
            .with_router("roundrobin")
            .with_chaos(chaos),
    );
    assert_eq!(clean, survivor, "losing a node changed KNN classifications");
    assert_eq!(stats.nodes_killed, 1, "{stats:?}");
    // Recovery replays tasks, not runs: only the lost subgraph re-executes.
    assert!(
        stats.lineage_resubmissions < stats.tasks_done,
        "{stats:?}"
    );
}

#[test]
fn kmeans_losing_a_node_mid_run_matches_single_node_results() {
    let mut cfg = KmeansConfig::small(11);
    cfg.shapes = tiny_shapes();
    cfg.fragments = 4;
    cfg.iterations = 3;
    cfg.tol = None;
    let run = |config: RuntimeConfig| {
        let rt = CompssRuntime::start(config).unwrap();
        let res = kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
        let stats = rt.stop().unwrap();
        (res.centroids, stats)
    };
    let (clean, _) = run(RuntimeConfig::local(2).with_chaos(ChaosSpec::default()));
    let chaos = ChaosSpec::parse("node-kill,seed:3").unwrap();
    let (survivor, stats) = run(
        RuntimeConfig::local(2)
            .with_nodes(4, 2)
            .with_router("roundrobin")
            .with_chaos(chaos),
    );
    assert!(
        clean.all_equal(&survivor, 1e-9),
        "losing a node changed the K-means centroids"
    );
    assert_eq!(stats.nodes_killed, 1, "{stats:?}");
    assert!(stats.lineage_resubmissions < stats.tasks_done, "{stats:?}");
}

#[test]
fn kill_join_churn_quiesces_with_zero_dead_bytes() {
    // Elasticity property: a reduction tree survives two kills and two
    // rejoins at arbitrary points, the sum stays exact, and the store
    // quiesces — no dead-version bytes, no accumulated transfer state.
    let config = RuntimeConfig::local(2)
        .with_nodes(3, 2)
        .with_router("roundrobin")
        .with_chaos(ChaosSpec::default());
    let rt = CompssRuntime::start(config).unwrap();
    let add = rt.register_task(TaskDef::new("add", 2, |a| {
        Ok(vec![RValue::scalar(
            a[0].as_f64().unwrap() + a[1].as_f64().unwrap(),
        )])
    }));
    let values: Vec<f64> = (0..16).map(|i| (i * i) as f64).collect();
    let mut layer: Vec<rcompss::api::TaskArg> =
        values.iter().map(|v| rcompss::api::TaskArg::from(*v)).collect();
    let mut round = 0;
    while layer.len() > 1 {
        let mut next = Vec::new();
        let mut it = layer.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => {
                    let r = rt.submit(&add, &[a, b]).unwrap();
                    next.push(rcompss::api::TaskArg::from(r));
                }
                None => next.push(a),
            }
        }
        layer = next;
        // Churn between layers: kill a node with work in flight, bring it
        // back one layer later, then lose a different one.
        match round {
            0 => assert!(rt.kill_node(2), "kill of live node 2"),
            1 => {
                assert!(rt.add_node(2), "rejoin of node 2");
                assert!(rt.kill_node(1), "kill of live node 1");
            }
            2 => assert!(rt.add_node(1), "rejoin of node 1"),
            _ => {}
        }
        round += 1;
    }
    let total = match layer.pop().unwrap() {
        rcompss::api::TaskArg::Future(r) => rt.wait_on(&r).unwrap().as_f64().unwrap(),
        rcompss::api::TaskArg::Value(v) => v.as_f64().unwrap(),
    };
    assert_eq!(total, values.iter().sum::<f64>(), "churn changed the sum");
    let stats = rt.stop().unwrap();
    assert_eq!(stats.nodes_killed, 2, "{stats:?}");
    assert_eq!(stats.nodes_joined, 2, "{stats:?}");
    assert_eq!(stats.dead_version_bytes, 0, "{stats:?}");
    // Kill/join churn must not leak transfer-board entries: at quiescence
    // only uncollected versions (the pinned final sum, terminal stragglers)
    // may keep any.
    assert!(
        stats.transfer_states <= 32,
        "transfer state survived churn: {stats:?}"
    );
}

#[test]
fn wait_on_and_barrier_report_the_root_cause() {
    let mut config = RuntimeConfig::local(2).with_chaos(ChaosSpec::default());
    config.injector = Arc::new(FailureInjector::new(1.0, "boom", u32::MAX, 5));
    let rt = CompssRuntime::start(config).unwrap();
    let boom = rt.register_task(TaskDef::new("boom_task", 0, |_| {
        Ok(vec![RValue::scalar(1.0)])
    }));
    let double = rt.register_task(TaskDef::new("double", 1, |a| {
        Ok(vec![RValue::scalar(2.0 * a[0].as_f64().unwrap())])
    }));
    let a = rt.submit(&boom, &[]).unwrap();
    let b = rt.submit(&double, &[a.into()]).unwrap();

    // The dependent's error names the failed ancestor, its type and its
    // attempt count — not just "cancelled".
    let err_b = rt.wait_on(&b).unwrap_err().to_string();
    assert!(err_b.contains("cancelled by failed ancestor"), "{err_b}");
    assert!(err_b.contains("boom_task"), "{err_b}");
    assert!(err_b.contains("attempt"), "{err_b}");

    // The root itself reports a permanent failure with its blurb.
    let err_a = rt.wait_on(&a).unwrap_err().to_string();
    assert!(err_a.contains("failed permanently"), "{err_a}");
    assert!(err_a.contains("boom_task"), "{err_a}");

    // Barrier appends the root cause of the failed DAG.
    let err_bar = rt.barrier().unwrap_err().to_string();
    assert!(err_bar.contains("root cause"), "{err_bar}");
    assert!(err_bar.contains("boom_task"), "{err_bar}");
    rt.stop().unwrap();
}
