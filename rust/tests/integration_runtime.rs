//! Integration tests across the full stack: apps x codecs x schedulers,
//! fault injection under load, DAG parity between live and simulated
//! executions, and the PJRT path (when artifacts are present).

use std::sync::Arc;

use rcompss::api::{CompssRuntime, RuntimeConfig};
use rcompss::apps::backend::Backend;
use rcompss::apps::kmeans::{self, KmeansConfig};
use rcompss::apps::knn::{self, KnnConfig};
use rcompss::apps::linreg::{self, LinregConfig};
use rcompss::apps::{LiveSink, Shapes};
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::coordinator::fault::FailureInjector;
use rcompss::sim::{CostModel, SimEngine, SimSink};

/// True when the CI chaos matrix is driving this run (`RCOMPSS_CHAOS`):
/// injected task failures and node kills make strict performance-counter
/// assertions (zero failed transfers, single encodes, ...) meaningless —
/// the *result* assertions stay in force, which is the whole point of the
/// matrix.
fn chaos_active() -> bool {
    std::env::var("RCOMPSS_CHAOS").map_or(false, |v| {
        rcompss::coordinator::fault::ChaosSpec::parse(&v)
            .map_or(false, |s| s.is_active())
    })
}

fn tiny_shapes() -> Shapes {
    Shapes {
        knn_train_n: 128,
        knn_test_block: 32,
        knn_d: 8,
        knn_k: 3,
        knn_classes: 3,
        km_frag_n: 96,
        km_d: 4,
        km_k: 3,
        lr_frag_n: 64,
        lr_p: 8,
        lr_pred_block: 32,
        ..Shapes::default()
    }
}

#[test]
fn knn_is_deterministic_across_codecs_and_policies() {
    // Pinned to the seed-identical file plane (budget 0, GC off): this
    // test is the codec coverage — every parameter must actually round-
    // trip through each codec, which the default memory plane would elide.
    let mut reference: Option<Vec<i32>> = None;
    for codec in ["rmvl", "qs", "fst", "rawbin", "serialize_rcpp"] {
        for policy in ["fifo", "locality"] {
            let rt = CompssRuntime::start(
                RuntimeConfig::local(3)
                    .with_codec(codec)
                    .with_scheduler(policy)
                    .with_memory_budget(0)
                    .with_gc(false),
            )
            .unwrap();
            let mut cfg = KnnConfig::small(5);
            cfg.shapes = tiny_shapes();
            cfg.train_fragments = 3;
            cfg.test_blocks = 1;
            let mut sink = LiveSink::new(
                &rt,
                rcompss::apps::backend::knn_task_defs(cfg.shapes, Backend::Native),
            );
            let plan = knn::plan_knn(&mut sink, &cfg).unwrap();
            let classes = sink.fetch(plan.classes[0]).unwrap();
            let got = classes.as_int().unwrap().to_vec();
            rt.stop().unwrap();
            match &reference {
                None => reference = Some(got),
                Some(want) => assert_eq!(
                    &got, want,
                    "codec {codec} policy {policy} changed results"
                ),
            }
        }
    }
}

#[test]
fn kmeans_survives_heavy_failure_injection() {
    // A third of partial_sum executions fail (budget-capped); resubmission
    // must still converge to the same centroids as a clean run. (With the
    // default 2-retry policy, p=0.35 keeps the chance of a task failing
    // three times in a row ~4% — the seed below is verified green.)
    let clean = {
        let rt = CompssRuntime::start(RuntimeConfig::local(3)).unwrap();
        let mut cfg = KmeansConfig::small(11);
        cfg.shapes = tiny_shapes();
        cfg.fragments = 3;
        cfg.iterations = 3;
        let res = kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
        rt.stop().unwrap();
        res.centroids
    };
    let mut config = RuntimeConfig::local(3);
    config.injector = Arc::new(FailureInjector::new(0.35, "partial_sum", 6, 77));
    // A wider retry budget than the COMPSs default so injected streaks
    // cannot exhaust it — this test is about result preservation, not the
    // budget boundary (covered by `exhausted_retries_...` below).
    config.retry = rcompss::coordinator::fault::RetryPolicy { max_retries: 6 };
    let rt = CompssRuntime::start(config).unwrap();
    let mut cfg = KmeansConfig::small(11);
    cfg.shapes = tiny_shapes();
    cfg.fragments = 3;
    cfg.iterations = 3;
    let res = kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
    let stats = rt.stop().unwrap();
    assert!(stats.resubmissions > 0, "injector must have fired");
    assert_eq!(stats.tasks_failed, 0, "no permanent failures within budget");
    assert!(
        clean.all_equal(&res.centroids, 1e-9),
        "failure injection changed the result"
    );
}

#[test]
fn exhausted_retries_cancel_downstream_but_runtime_survives() {
    let mut config = RuntimeConfig::local(2);
    // Infinite budget, always fail KNN_frag -> permanent failure.
    config.injector = Arc::new(FailureInjector::new(1.0, "KNN_frag", u32::MAX, 3));
    let rt = CompssRuntime::start(config).unwrap();
    let mut cfg = KnnConfig::small(9);
    cfg.shapes = tiny_shapes();
    cfg.train_fragments = 2;
    cfg.test_blocks = 1;
    let err = knn::run_knn(&rt, &cfg, Backend::Native);
    assert!(err.is_err(), "run must surface the failure");
    let stats = rt.stop().unwrap();
    assert!(stats.tasks_failed > 0);
    assert!(stats.tasks_cancelled > 0, "downstream tasks cancelled");
}

#[test]
fn live_and_simulated_dags_match_for_all_apps() {
    let shapes = tiny_shapes();
    // KNN.
    {
        let mut cfg = KnnConfig::small(2);
        cfg.shapes = shapes;
        cfg.train_fragments = 4;
        cfg.test_blocks = 2;
        let rt = CompssRuntime::start(RuntimeConfig::local(3)).unwrap();
        knn::run_knn(&rt, &cfg, Backend::Native).unwrap();
        let live = rt.stop().unwrap();
        let mut sink = SimSink::new();
        knn::plan_knn(&mut sink, &cfg).unwrap();
        let sim = sink.finish().type_counts();
        for (ty, (count, _)) in &live.per_type {
            assert_eq!(sim.get(ty).copied(), Some(*count as usize), "knn {ty}");
        }
    }
    // K-means (fixed iterations so live == plan).
    {
        let mut cfg = KmeansConfig::small(2);
        cfg.shapes = shapes;
        cfg.fragments = 3;
        cfg.iterations = 2;
        cfg.tol = None;
        let rt = CompssRuntime::start(RuntimeConfig::local(3)).unwrap();
        kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
        let live = rt.stop().unwrap();
        let mut sink = SimSink::new();
        kmeans::plan_kmeans(&mut sink, &cfg).unwrap();
        let sim = sink.finish().type_counts();
        for (ty, (count, _)) in &live.per_type {
            assert_eq!(sim.get(ty).copied(), Some(*count as usize), "kmeans {ty}");
        }
    }
    // Linreg.
    {
        let mut cfg = LinregConfig::small(2);
        cfg.shapes = shapes;
        cfg.fragments = 4;
        cfg.pred_blocks = 2;
        let rt = CompssRuntime::start(RuntimeConfig::local(3)).unwrap();
        linreg::run_linreg(&rt, &cfg, Backend::Native).unwrap();
        let live = rt.stop().unwrap();
        let mut sink = SimSink::new();
        linreg::plan_linreg(&mut sink, &cfg).unwrap();
        let sim = sink.finish().type_counts();
        for (ty, (count, _)) in &live.per_type {
            assert_eq!(sim.get(ty).copied(), Some(*count as usize), "linreg {ty}");
        }
    }
}

#[test]
fn simulated_scaling_is_sane_for_all_apps_and_machines() {
    // Strong scaling 1 -> 8 workers must speed up every app on every
    // machine, and efficiency must stay within (0, 1].
    for profile in [MachineProfile::shaheen3(), MachineProfile::marenostrum5()] {
        for app in ["knn", "kmeans", "linreg"] {
            let plan = |_w: u32| match app {
                "knn" => rcompss::sim::plans::knn_plan(4, 16, 1).unwrap(),
                "kmeans" => rcompss::sim::plans::kmeans_plan(16, 2, 1).unwrap(),
                _ => rcompss::sim::plans::linreg_plan(16, 4, 1).unwrap(),
            };
            let t1 = SimEngine::new(
                ClusterSpec::new(profile.clone(), 1).with_workers_per_node(1),
                CostModel::default(),
            )
            .run(plan(1), "s1")
            .unwrap()
            .makespan_s;
            let t8 = SimEngine::new(
                ClusterSpec::new(profile.clone(), 1).with_workers_per_node(8),
                CostModel::default(),
            )
            .run(plan(8), "s8")
            .unwrap()
            .makespan_s;
            assert!(
                t8 < t1,
                "{app}@{}: 8 workers ({t8:.2}s) not faster than 1 ({t1:.2}s)",
                profile.name
            );
            let eff = t1 / (8.0 * t8);
            assert!(eff <= 1.05, "{app}@{}: superlinear {eff}", profile.name);
        }
    }
}

#[test]
fn pjrt_backend_agrees_with_native_on_linreg() {
    if !rcompss::runtime::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Artifact shapes are required for the PJRT backend.
    let cfg = {
        let mut c = LinregConfig::small(4);
        c.fragments = 2;
        c.pred_blocks = 1;
        c
    };
    let run = |backend| {
        let rt = CompssRuntime::start(RuntimeConfig::local(2)).unwrap();
        let res = linreg::run_linreg(&rt, &cfg, backend).unwrap();
        rt.stop().unwrap();
        res
    };
    let p = run(Backend::Pjrt);
    let n = run(Backend::Native);
    assert!(p.beta.all_equal(&n.beta, 1e-2), "backends disagree on beta");
    assert!(p.r2 > 0.95 && n.r2 > 0.95);
}

#[test]
fn trace_of_live_run_covers_all_task_types() {
    let rt = CompssRuntime::start(RuntimeConfig::local(3).with_trace(true)).unwrap();
    let mut cfg = KnnConfig::small(6);
    cfg.shapes = tiny_shapes();
    cfg.train_fragments = 3;
    cfg.test_blocks = 1;
    knn::run_knn(&rt, &cfg, Backend::Native).unwrap();
    let trace = rt.trace("live knn");
    rt.stop().unwrap();
    let types: std::collections::HashSet<String> = trace
        .events
        .iter()
        .filter_map(|e| match &e.kind {
            rcompss::trace::EventKind::TaskExec(ty) => Some(ty.to_string()),
            _ => None,
        })
        .collect();
    for ty in ["KNN_fill_fragment", "KNN_fill_test", "KNN_frag", "KNN_merge", "KNN_classify"] {
        assert!(types.contains(ty), "trace missing {ty}");
    }
    assert!(trace.utilization() > 0.0);
    assert!(trace.to_prv().starts_with("#Paraver"));
}

#[test]
fn memory_plane_matches_file_plane_results() {
    // The in-memory data plane must be semantically invisible: the same
    // KNN run classifies identically with the store on or off, across
    // schedulers.
    let mut reference: Option<Vec<i32>> = None;
    for budget in [0u64, 256 << 20] {
        for policy in ["fifo", "locality"] {
            let rt = CompssRuntime::start(
                RuntimeConfig::local(3)
                    .with_scheduler(policy)
                    .with_memory_budget(budget),
            )
            .unwrap();
            let mut cfg = KnnConfig::small(5);
            cfg.shapes = tiny_shapes();
            cfg.train_fragments = 3;
            cfg.test_blocks = 1;
            let mut sink = LiveSink::new(
                &rt,
                rcompss::apps::backend::knn_task_defs(cfg.shapes, Backend::Native),
            );
            let plan = knn::plan_knn(&mut sink, &cfg).unwrap();
            let classes = sink.fetch(plan.classes[0]).unwrap();
            let got = classes.as_int().unwrap().to_vec();
            rt.stop().unwrap();
            match &reference {
                None => reference = Some(got),
                Some(want) => assert_eq!(
                    &got, want,
                    "budget {budget} policy {policy} changed results"
                ),
            }
        }
    }
}

#[test]
fn flipped_defaults_run_memory_plane_with_gc_and_stay_clean() {
    // The data-plane defaults are now ON: a plain `local()` config must
    // run the 256 MiB memory plane with the version GC, finish with zero
    // dead-version bytes, and never decode a transfer synchronously.
    let mut cfg = KmeansConfig::small(11);
    cfg.shapes = tiny_shapes();
    cfg.fragments = 3;
    cfg.iterations = 3;
    cfg.tol = None;
    let config = RuntimeConfig::local(3);
    assert_eq!(
        config.memory_budget,
        rcompss::coordinator::runtime::DEFAULT_MEMORY_BUDGET,
        "single source of truth for the default budget"
    );
    assert!(config.gc, "version GC defaults on");
    let rt = CompssRuntime::start(config).unwrap();
    kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
    let stats = rt.stop().unwrap();
    assert!(stats.store_hits > 0, "memory plane active: {stats:?}");
    assert!(stats.gc_collected > 0, "GC active: {stats:?}");
    assert_eq!(stats.dead_version_bytes, 0, "{stats:?}");
    assert_eq!(stats.sync_transfer_decodes, 0, "{stats:?}");
}

#[test]
fn every_router_produces_identical_results() {
    // Placement is a performance decision, never a semantic one: the same
    // 2-node KNN run must classify identically under every model.
    let mut cfg = KnnConfig::small(5);
    cfg.shapes = tiny_shapes();
    cfg.train_fragments = 4;
    cfg.test_blocks = 2;
    let mut reference: Option<Vec<i32>> = None;
    for router in ["bytes", "cost", "roundrobin", "adaptive"] {
        let rt = CompssRuntime::start(
            RuntimeConfig::local(2).with_nodes(2, 2).with_router(router),
        )
        .unwrap();
        let mut sink = LiveSink::new(
            &rt,
            rcompss::apps::backend::knn_task_defs(cfg.shapes, Backend::Native),
        );
        let plan = knn::plan_knn(&mut sink, &cfg).unwrap();
        let classes = sink.fetch(plan.classes[0]).unwrap();
        let got = classes.as_int().unwrap().to_vec();
        let stats = rt.stop().unwrap();
        if !chaos_active() {
            assert_eq!(stats.sync_transfer_decodes, 0, "router {router}: {stats:?}");
            assert_eq!(stats.dead_version_bytes, 0, "router {router}: {stats:?}");
        }
        match &reference {
            None => reference = Some(got),
            Some(want) => assert_eq!(&got, want, "router {router} changed results"),
        }
    }
    // Unknown models are rejected at startup.
    assert!(CompssRuntime::start(RuntimeConfig::local(1).with_router("zzz")).is_err());
}

#[test]
fn node_local_chain_performs_zero_file_io() {
    // Regression test for the zero-copy data plane: a node-local RAW chain
    // with a comfortable budget must never touch the codec or the workdir.
    let config = RuntimeConfig::local_in_memory(2);
    let workdir = config.workdir.clone();
    let rt = CompssRuntime::start(config).unwrap();
    let double = rt.register_task(rcompss::api::TaskDef::new("double", 1, |a| {
        let x = a[0].as_f64().ok_or_else(|| anyhow::anyhow!("not scalar"))?;
        Ok(vec![rcompss::value::RValue::scalar(2.0 * x)])
    }));
    let mut r = rt.submit(&double, &[1.0.into()]).unwrap();
    for _ in 0..6 {
        r = rt.submit(&double, &[r.into()]).unwrap();
    }
    let v = rt.wait_on(&r).unwrap();
    assert_eq!(v.as_f64(), Some(128.0));
    let files: Vec<_> = std::fs::read_dir(&workdir).unwrap().collect();
    assert!(
        files.is_empty(),
        "node-local chain wrote {} parameter file(s)",
        files.len()
    );
    let stats = rt.stop().unwrap();
    assert_eq!(stats.spills, 0);
    assert_eq!(stats.store_misses, 0);
    assert_eq!(stats.bytes_serialized + stats.bytes_deserialized, 0);
    assert!(stats.store_hits >= 8, "7 task inputs + 1 wait_on: {stats:?}");
}

#[test]
fn spill_reload_roundtrips_through_every_codec() {
    // LRU spill + reload must be exact for each Table-1 codec: a tiny
    // budget forces every intermediate out through the codec and back.
    // GC pinned off — reclaiming drained intermediates would relieve the
    // memory pressure this test depends on — and the warm tier pinned off
    // so demotions land on actual files (the warm-tier sibling of this
    // coverage is `warm_tier_roundtrips_through_every_codec`).
    for codec in ["rmvl", "qs", "fst", "rawbin", "serialize_rcpp", "rds", "csv"] {
        let config = RuntimeConfig::local(2)
            .with_codec(codec)
            .with_memory_budget(96)
            .with_warm_budget(0)
            .with_spill("lru")
            .with_gc(false);
        let rt = CompssRuntime::start(config).unwrap();
        let add = rt.register_task(rcompss::api::TaskDef::new("add", 2, |a| {
            let x = a[0].as_f64().unwrap();
            let y = a[1].as_f64().unwrap();
            Ok(vec![rcompss::value::RValue::scalar(x + y)])
        }));
        let mut acc = rt.submit(&add, &[0.25.into(), 0.5.into()]).unwrap();
        for i in 1..=8 {
            acc = rt.submit(&add, &[acc.into(), (i as f64 + 0.125).into()]).unwrap();
        }
        let v = rt.wait_on(&acc).unwrap();
        assert_eq!(v.as_f64(), Some(0.75 + 36.0 + 8.0 * 0.125), "codec {codec}");
        let stats = rt.stop().unwrap();
        assert!(stats.spills > 0, "codec {codec}: tiny budget must spill");
    }
}

#[test]
fn largest_spill_policy_also_preserves_results() {
    let config = RuntimeConfig::local(3)
        .with_memory_budget(1 << 10)
        .with_spill("largest");
    let rt = CompssRuntime::start(config).unwrap();
    let mut cfg = KmeansConfig::small(11);
    cfg.shapes = tiny_shapes();
    cfg.fragments = 3;
    cfg.iterations = 2;
    cfg.tol = None;
    let res = kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
    let stats = rt.stop().unwrap();
    assert!(stats.spills > 0, "1 KiB budget must spill: {stats:?}");

    let rt = CompssRuntime::start(RuntimeConfig::local(3)).unwrap();
    let clean = kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
    rt.stop().unwrap();
    assert!(
        clean.centroids.all_equal(&res.centroids, 1e-9),
        "spilling changed the k-means result"
    );
}

#[test]
fn memory_plane_multi_node_transfers_through_codec() {
    // Cross-node consumption is a spill boundary: a 2-node run must work,
    // agree with single-node results, and exercise the codec.
    let mut cfg = KnnConfig::small(5);
    cfg.shapes = tiny_shapes();
    cfg.train_fragments = 3;
    cfg.test_blocks = 1;
    let run = |nodes: u32, wpn: u32, budget: u64| {
        let rt = CompssRuntime::start(
            RuntimeConfig::local(2)
                .with_nodes(nodes, wpn)
                .with_memory_budget(budget),
        )
        .unwrap();
        let mut sink = LiveSink::new(
            &rt,
            rcompss::apps::backend::knn_task_defs(cfg.shapes, Backend::Native),
        );
        let plan = knn::plan_knn(&mut sink, &cfg).unwrap();
        let classes = sink.fetch(plan.classes[0]).unwrap();
        let got = classes.as_int().unwrap().to_vec();
        rt.stop().unwrap();
        got
    };
    let single = run(1, 2, 256 << 20);
    let multi = run(2, 2, 256 << 20);
    assert_eq!(single, multi, "node count changed classification");
}

#[test]
fn gc_chain_returns_store_bytes_to_zero() {
    // Version-GC acceptance: after a RAW chain fully consumes its
    // intermediates, the store holds (at most) the pinned final value and
    // no dead-version bytes remain.
    let config = RuntimeConfig::local_in_memory(2).with_gc(true);
    let workdir = config.workdir.clone();
    let rt = CompssRuntime::start(config).unwrap();
    let double = rt.register_task(rcompss::api::TaskDef::new("double", 1, |a| {
        let x = a[0].as_f64().ok_or_else(|| anyhow::anyhow!("not scalar"))?;
        Ok(vec![rcompss::value::RValue::scalar(2.0 * x)])
    }));
    let mut r = rt.submit(&double, &[1.0.into()]).unwrap();
    for _ in 0..9 {
        r = rt.submit(&double, &[r.into()]).unwrap();
    }
    let v = rt.wait_on(&r).unwrap();
    assert_eq!(v.as_f64(), Some(1024.0));
    let files: Vec<_> = std::fs::read_dir(&workdir).unwrap().collect();
    assert!(files.is_empty(), "comfortable budget: no files at all");
    let stats = rt.stop().unwrap();
    assert_eq!(stats.dead_version_bytes, 0, "{stats:?}");
    assert!(stats.gc_collected >= 9, "9 intermediates + 10 literals: {stats:?}");
    assert!(
        stats.store_resident_bytes <= 64,
        "only the pinned final scalar may remain: {stats:?}"
    );
}

#[test]
fn gc_deletes_spill_files_of_collected_versions() {
    // A tiny budget forces intermediates through the codec onto disk; the
    // GC must delete those spill files as the versions drain, not leave
    // them for pressure-era cleanup. (10 bytes: even two scalars overflow,
    // so spilling is deterministic regardless of how fast the GC drains.
    // Warm tier pinned off so demotions actually reach the cold tier.)
    let config = RuntimeConfig::local(2)
        .with_memory_budget(10)
        .with_warm_budget(0)
        .with_spill("lru")
        .with_gc(true);
    let workdir = config.workdir.clone();
    let rt = CompssRuntime::start(config).unwrap();
    let add = rt.register_task(rcompss::api::TaskDef::new("add", 2, |a| {
        Ok(vec![rcompss::value::RValue::scalar(
            a[0].as_f64().unwrap() + a[1].as_f64().unwrap(),
        )])
    }));
    let mut acc = rt.submit(&add, &[0.0.into(), 1.0.into()]).unwrap();
    for i in 2..=10 {
        acc = rt.submit(&add, &[acc.into(), (i as f64).into()]).unwrap();
    }
    let v = rt.wait_on(&acc).unwrap();
    assert_eq!(v.as_f64(), Some(55.0));
    rt.barrier().unwrap();
    // Read the workdir before stop() (which removes it). Barrier precedes
    // the last couple of input releases by a hair, so allow one lagging
    // file per worker besides the pinned final version.
    let files: Vec<String> = std::fs::read_dir(&workdir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    let stats = rt.stop().unwrap();
    assert!(stats.spills > 0, "10 B budget must spill: {stats:?}");
    assert!(stats.gc_files_deleted > 0, "GC must delete spill files: {stats:?}");
    assert_eq!(stats.dead_version_bytes, 0, "{stats:?}");
    // Whatever survives on disk belongs to live (pinned/terminal)
    // versions or a not-yet-released straggler, never the bulk of the
    // drained intermediates (21 versions passed through this run).
    assert!(
        files.len() <= 3,
        "drained intermediates must not linger on disk: {files:?}"
    );
}

#[test]
fn gc_file_plane_deletes_consumed_parameter_files() {
    // The GC also applies to the pure file plane: a consumed dXvY's
    // parameter file is deleted instead of accumulating in the workdir.
    let config = RuntimeConfig::local(2).with_memory_budget(0).with_gc(true);
    let workdir = config.workdir.clone();
    let rt = CompssRuntime::start(config).unwrap();
    let double = rt.register_task(rcompss::api::TaskDef::new("double", 1, |a| {
        Ok(vec![rcompss::value::RValue::scalar(2.0 * a[0].as_f64().unwrap())])
    }));
    let mut r = rt.submit(&double, &[1.0.into()]).unwrap();
    for _ in 0..7 {
        r = rt.submit(&double, &[r.into()]).unwrap();
    }
    assert_eq!(rt.wait_on(&r).unwrap().as_f64(), Some(256.0));
    rt.barrier().unwrap();
    let files: Vec<String> = std::fs::read_dir(&workdir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    let stats = rt.stop().unwrap();
    assert!(stats.gc_files_deleted >= 7, "{stats:?}");
    assert!(
        files.len() <= 3,
        "only the pinned final version (plus at most a straggling
         not-yet-released input) may keep a file: {files:?}"
    );
}

#[test]
fn kmeans_memory_plane_gc_ends_with_zero_dead_bytes() {
    // Acceptance criterion: a full app run (K-means, memory plane, GC on)
    // ends with zero live dead-version bytes in the store, and the result
    // is identical to a GC-off run.
    let mut cfg = KmeansConfig::small(11);
    cfg.shapes = tiny_shapes();
    cfg.fragments = 3;
    cfg.iterations = 3;
    cfg.tol = None;
    let baseline = {
        let rt = CompssRuntime::start(RuntimeConfig::local(3)).unwrap();
        let res = kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
        rt.stop().unwrap();
        res.centroids
    };
    let rt = CompssRuntime::start(RuntimeConfig::local_in_memory(3).with_gc(true)).unwrap();
    let res = kmeans::run_kmeans(&rt, &cfg, Backend::Native).unwrap();
    let stats = rt.stop().unwrap();
    assert!(
        baseline.all_equal(&res.centroids, 1e-9),
        "GC changed the k-means result"
    );
    assert_eq!(stats.dead_version_bytes, 0, "{stats:?}");
    assert!(stats.gc_collected > 0, "fragments and partials drain: {stats:?}");
    // The fragments dominate the working set; after the last iteration
    // consumed them they are reclaimed, so the residual store footprint is
    // below a single fragment.
    let frag_bytes = (cfg.shapes.km_frag_n * cfg.shapes.km_d * 8) as u64;
    assert!(
        stats.store_resident_bytes < frag_bytes,
        "resident {} >= one fragment {}: {stats:?}",
        stats.store_resident_bytes,
        frag_bytes
    );
}

#[test]
fn two_node_memory_plane_claims_never_run_codec_synchronously() {
    // Async-transfer acceptance: on a 2-node memory-plane run, cross-node
    // consumption is staged by mover threads — the claim path never calls
    // the codec synchronously (DataStore counter stays zero) — and the
    // results match the single-node run.
    let mut cfg = KnnConfig::small(5);
    cfg.shapes = tiny_shapes();
    cfg.train_fragments = 4;
    cfg.test_blocks = 2;
    let run = |nodes: u32| {
        let rt = CompssRuntime::start(
            RuntimeConfig::local(2)
                .with_nodes(nodes, 2)
                .with_memory_budget(256 << 20)
                .with_gc(true),
        )
        .unwrap();
        let mut sink = LiveSink::new(
            &rt,
            rcompss::apps::backend::knn_task_defs(cfg.shapes, Backend::Native),
        );
        let plan = knn::plan_knn(&mut sink, &cfg).unwrap();
        let classes = sink.fetch(plan.classes[0]).unwrap();
        let got = classes.as_int().unwrap().to_vec();
        let stats = rt.stop().unwrap();
        (got, stats)
    };
    let (single, _) = run(1);
    let (multi, stats) = run(2);
    assert_eq!(single, multi, "node count changed classification");
    if chaos_active() {
        // Injected transfer failures / node kills legitimately perturb the
        // counters below; the result equality above is the chaos contract.
        return;
    }
    assert_eq!(
        stats.sync_transfer_decodes, 0,
        "claim paths must never run the codec for cross-node inputs: {stats:?}"
    );
    assert_eq!(stats.transfers_failed, 0, "{stats:?}");
    assert_eq!(stats.dead_version_bytes, 0, "{stats:?}");
    // Any data movement that did happen was performed by the movers, and
    // every request was drained before shutdown: staged (prefetched or
    // waited-on), dropped (replica raced ahead / version reclaimed), or
    // failed (counted per attempt; zero here).
    assert_eq!(
        stats.transfers_prefetched
            + stats.transfers_waited
            + stats.transfers_dropped
            + stats.transfers_failed,
        stats.transfers_requested,
        "transfer accounting is consistent: {stats:?}"
    );
    // The GC purges a collected version's transfer-board entries, so the
    // state map cannot grow with tasks x inputs: at quiescence only
    // uncollected versions (pinned results, terminal outputs) may keep
    // entries.
    assert!(
        stats.transfer_states <= 16,
        "transfer tombstones must not accumulate: {stats:?}"
    );
}

#[test]
fn warm_fanout_transfers_encode_once_with_zero_file_io() {
    // Tiered-store acceptance (public stats surface): one producer's
    // output consumed across a 4-node fabric performs exactly 1 encode and
    // 0 file reads/writes with the warm tier on — the movers ship the
    // cached blob — while `--warm-budget 0` reproduces the file-staging
    // behavior (spill file written, read back per destination) with
    // identical results. Round-robin routing spreads the consumers so the
    // fan-out is guaranteed; warm budget pinned explicitly so the CI env
    // matrix cannot flip it under the test.
    use rcompss::api::TaskDef;
    use rcompss::value::RValue;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::{Duration, Instant};
    let run = |warm: u64| {
        let rt = CompssRuntime::start(
            RuntimeConfig::local(1)
                .with_nodes(4, 1)
                .with_router("roundrobin")
                .with_warm_budget(warm),
        )
        .unwrap();
        let mk = rt.register_task(TaskDef::new("mk", 0, |_| {
            Ok(vec![RValue::Real(vec![1.25; 4096])])
        }));
        // Consumers block on the gate until every remote replica is
        // staged: the transfer counts below are then deterministic — no
        // steal/GC race can drop a queued transfer, because the blocked
        // consumers hold their input references the whole time.
        let gate = Arc::new(AtomicBool::new(false));
        let consume = {
            let gate = Arc::clone(&gate);
            rt.register_task(TaskDef::new("consume", 1, move |a| {
                while !gate.load(Ordering::Acquire) {
                    std::thread::yield_now();
                }
                Ok(vec![RValue::scalar(a[0].as_real().unwrap().iter().sum())])
            }))
        };
        let src = rt.submit(&mk, &[]).unwrap();
        let outs: Vec<_> = (0..8)
            .map(|_| rt.submit(&consume, &[src.into()]).unwrap())
            .collect();
        // Round-robin routes consumers to every node, so enqueue_ready
        // prefetches `src` toward nodes 1..3 at schedule time; the movers
        // stage those three replicas regardless of worker progress.
        let t0 = Instant::now();
        loop {
            let s = rt.stats();
            if s.transfers_prefetched + s.transfers_waited >= 3 {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "fan-out staging never completed: {s:?}"
            );
            std::thread::yield_now();
        }
        gate.store(true, Ordering::Release);
        let mut total = 0.0;
        for o in &outs {
            total += rt.wait_on(o).unwrap().as_f64().unwrap();
        }
        let stats = rt.stop().unwrap();
        (total, stats)
    };
    let (warm_total, warm_stats) =
        run(rcompss::coordinator::runtime::DEFAULT_WARM_BUDGET);
    assert_eq!(warm_total, 8.0 * 1.25 * 4096.0);
    if !chaos_active() {
        assert_eq!(warm_stats.store_encodes, 1, "{warm_stats:?}");
        assert_eq!(warm_stats.store_file_reads, 0, "{warm_stats:?}");
        assert_eq!(warm_stats.store_file_writes, 0, "{warm_stats:?}");
        assert!(warm_stats.warm_hits >= 1, "fan-out replicas hit warm: {warm_stats:?}");
        assert_eq!(warm_stats.sync_transfer_decodes, 0, "{warm_stats:?}");
        // The GC reclaimed the fanned-out version from every tier.
        assert_eq!(warm_stats.warm_resident_bytes, 0, "{warm_stats:?}");
        assert_eq!(warm_stats.dead_version_bytes, 0, "{warm_stats:?}");
    }

    let (file_total, file_stats) = run(0);
    assert_eq!(file_total, warm_total, "staging path changed results");
    if !chaos_active() {
        assert!(
            file_stats.store_file_writes >= 1,
            "file staging must publish the spill file: {file_stats:?}"
        );
        assert!(
            file_stats.store_file_reads >= 1,
            "file staging must read it back: {file_stats:?}"
        );
        assert_eq!(
            file_stats.warm_hits + file_stats.warm_fills,
            0,
            "warm tier off must see no traffic: {file_stats:?}"
        );
        assert_eq!(file_stats.sync_transfer_decodes, 0, "{file_stats:?}");
    }
}

#[test]
fn warm_tier_roundtrips_through_every_codec() {
    // A hot budget far below the working set demotes every intermediate
    // into the warm tier; reloads decode the cached blob. The chain must
    // stay exact for each Table-1 codec and the filesystem must never be
    // touched — the warm tier absorbs what used to be spill files.
    for codec in ["rmvl", "qs", "fst", "rawbin", "serialize_rcpp", "rds", "csv"] {
        let config = RuntimeConfig::local(2)
            .with_codec(codec)
            .with_memory_budget(96)
            .with_warm_budget(rcompss::coordinator::runtime::DEFAULT_WARM_BUDGET)
            .with_spill("lru")
            .with_gc(false);
        let workdir = config.workdir.clone();
        let rt = CompssRuntime::start(config).unwrap();
        let add = rt.register_task(rcompss::api::TaskDef::new("add", 2, |a| {
            let x = a[0].as_f64().unwrap();
            let y = a[1].as_f64().unwrap();
            Ok(vec![rcompss::value::RValue::scalar(x + y)])
        }));
        let mut acc = rt.submit(&add, &[0.25.into(), 0.5.into()]).unwrap();
        for i in 1..=8 {
            acc = rt.submit(&add, &[acc.into(), (i as f64 + 0.125).into()]).unwrap();
        }
        let v = rt.wait_on(&acc).unwrap();
        assert_eq!(v.as_f64(), Some(0.75 + 36.0 + 8.0 * 0.125), "codec {codec}");
        let files: Vec<_> = std::fs::read_dir(&workdir).unwrap().collect();
        assert!(
            files.is_empty(),
            "codec {codec}: warm tier must absorb demotions, found {} file(s)",
            files.len()
        );
        let stats = rt.stop().unwrap();
        assert!(stats.spills > 0, "codec {codec}: tiny hot budget must demote");
        assert!(stats.warm_hits > 0, "codec {codec}: reloads must hit warm: {stats:?}");
        assert_eq!(stats.store_file_writes, 0, "codec {codec}: {stats:?}");
        assert_eq!(stats.store_file_reads, 0, "codec {codec}: {stats:?}");
    }
}

#[test]
fn workdir_files_use_dxvy_naming() {
    // The on-disk parameter files carry the paper's dXvY labels. Pinned
    // to the seed-identical file plane: budget 0 so every parameter gets
    // a file, GC off so none of them is deleted before the scan.
    let config = RuntimeConfig::local(2).with_memory_budget(0).with_gc(false);
    let workdir = config.workdir.clone();
    let rt = CompssRuntime::start(config).unwrap();
    let mut cfg = KnnConfig::small(8);
    cfg.shapes = tiny_shapes();
    cfg.train_fragments = 2;
    cfg.test_blocks = 1;
    knn::run_knn(&rt, &cfg, Backend::Native).unwrap();
    let names: Vec<String> = std::fs::read_dir(&workdir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    assert!(!names.is_empty());
    assert!(
        names.iter().all(|n| n.starts_with('d') && n.contains('v') && n.ends_with(".par")),
        "unexpected names: {names:?}"
    );
    rt.stop().unwrap();
    assert!(!workdir.exists(), "stop() must clean the workdir");
}
