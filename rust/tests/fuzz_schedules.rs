//! Schedule-fuzz acceptance suite: the race-hunting harness end to end.
//!
//! Two planes under test. The **simulator** plane permutes timestamp-tied
//! events with a seeded PRNG (`SimEngine::with_fuzz_seed`) and
//! `fuzz_sweep` drives many seeds through one plan, asserting
//! schedule-independence invariants (results byte-identical across seeds,
//! zero dead version bytes, no stuck tasks) and naming the minimal
//! failing seed. The **live** plane arms deterministic yield points at the
//! runtime's hazard windows (`CoordinatorConfig::with_sched_fuzz`) so the
//! PR-4 class of transfer-board/GC races reproduces from a pinned seed.
//!
//! CI's fuzz-matrix job hands a fresh seed base per run via
//! `RCOMPSS_FUZZ_SEED_BASE` (the sweeps explore new schedules every run);
//! locally the base defaults to 1 so `cargo test` is deterministic. Any
//! failure message names the exact seed to replay.

use std::sync::Arc;

use rcompss::api::{CompssRuntime, RuntimeConfig, TaskDef};
use rcompss::apps::backend::Backend;
use rcompss::apps::kmeans::{self, KmeansConfig};
use rcompss::apps::Shapes;
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::coordinator::dag::TaskId;
use rcompss::coordinator::fault::{ChaosSpec, FailureInjector};
use rcompss::coordinator::placement::{placement_by_name, InflightSource, RoutedReady};
use rcompss::coordinator::registry::NodeId;
use rcompss::coordinator::scheduler::{ReadyTask, ShardedReady};
use rcompss::sim::plans::{kmeans_plan, knn_plan};
use rcompss::sim::{fleet_plan, CostModel, SimEngine};
use rcompss::value::RValue;

/// Seeds for one sweep: `base * 1000 + i`, with the base taken from
/// `RCOMPSS_FUZZ_SEED_BASE` (CI sets it from the run number) and
/// defaulting to 1. Distinct sweeps pass distinct `lane`s so the suite's
/// 64 seeds never overlap.
fn seeds(lane: u64, n: u64) -> Vec<u64> {
    let base = std::env::var("RCOMPSS_FUZZ_SEED_BASE")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or(1);
    (0..n)
        .map(|i| base.wrapping_mul(1000).wrapping_add(lane * 100 + i))
        .collect()
}

fn cluster(nodes: u32, wpn: u32) -> ClusterSpec {
    ClusterSpec::new(MachineProfile::shaheen3(), nodes).with_workers_per_node(wpn)
}

// ---------------------------------------------------------------------------
// Simulator plane: seeded sweeps over the three plan families.
// ---------------------------------------------------------------------------

#[test]
fn fuzz_sweep_transfer_heavy_plan_is_schedule_independent() {
    // KNN's train x test cross-product is the transfer-heavy family:
    // every test block consumes every train fragment, so the `cost`
    // router keeps the simulated transfer plane saturated. 24 seeds; the
    // sweep itself asserts drain, zero dead bytes, and cross-seed result
    // digests.
    let engine = SimEngine::new(cluster(4, 2), CostModel::default()).with_router("cost");
    let reports = engine
        .fuzz_sweep(&seeds(0, 24), || knn_plan(8, 4, 1), "knn-transfer-heavy")
        .unwrap();
    assert_eq!(reports.len(), 24);
    let done = reports[0].tasks_done;
    for r in &reports {
        assert!(r.fuzz_seed.is_some(), "sweep reports carry their seed");
        assert_eq!(r.tasks_done, done, "seed changed the completed-task count");
        assert_eq!(r.dead_version_bytes, 0, "seed {} leaked versions", r.fuzz_seed.unwrap());
    }
}

#[test]
fn fuzz_sweep_gc_heavy_plan_is_schedule_independent() {
    // K-means re-versions the centroids every iteration: each round kills
    // the previous round's versions, so event permutations stress GC
    // ordering against late consumers and transfers.
    let engine = SimEngine::new(cluster(4, 2), CostModel::default()).with_router("bytes");
    let reports = engine
        .fuzz_sweep(&seeds(1, 24), || kmeans_plan(8, 3, 1), "kmeans-gc-heavy")
        .unwrap();
    assert_eq!(reports.len(), 24);
    for r in &reports {
        assert_eq!(r.dead_version_bytes, 0, "seed {} leaked versions", r.fuzz_seed.unwrap());
    }
}

#[test]
fn fuzz_sweep_survives_kill_join_churn() {
    // Chaos family: a mid-run node kill plus a later rejoin, on top of the
    // event permutation. Cross-seed digest equality is deliberately not
    // asserted by the sweep here (the kill point lands differently per
    // schedule, so re-executed lineage differs); drain + zero dead bytes
    // must still hold for every seed.
    let base = SimEngine::new(cluster(4, 2), CostModel::default())
        .run(knn_plan(6, 3, 1).unwrap(), "baseline")
        .unwrap();
    let engine = SimEngine::new(cluster(4, 2), CostModel::default())
        .with_router("cost")
        .with_node_kill(base.makespan_s * 0.4, 3)
        .with_node_join(base.makespan_s * 0.7, 3);
    let reports = engine
        .fuzz_sweep(&seeds(2, 16), || knn_plan(6, 3, 1), "knn-kill-join")
        .unwrap();
    assert_eq!(reports.len(), 16);
    for r in &reports {
        assert!(
            r.tasks_done >= base.tasks_done,
            "seed {}: all tasks complete, re-runs included",
            r.fuzz_seed.unwrap()
        );
    }
}

#[test]
fn same_seed_replays_bit_identical_runs() {
    // The replay contract: one seed, one schedule — every derived number
    // is bit-equal run over run, so a CI-found seed reproduces exactly.
    let seed = seeds(3, 1)[0];
    let run = || {
        SimEngine::new(cluster(3, 2), CostModel::default())
            .with_router("cost")
            .with_fuzz_seed(seed)
            .run(knn_plan(8, 2, 1).unwrap(), "replay")
            .unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
    assert_eq!(a.total_io_s.to_bits(), b.total_io_s.to_bits());
    assert_eq!(a.total_transfer_s.to_bits(), b.total_transfer_s.to_bits());
    assert_eq!(a.result_digest, b.result_digest);
    assert_eq!(a.tasks_done, b.tasks_done);
}

#[test]
fn fuzz_sweep_names_the_minimal_failing_seed() {
    // A plan with its ready frontier withheld can never drain; every seed
    // fails, and the error must name the *smallest* seed plus the replay
    // protocol — that is the line CI greps into the job summary.
    let engine = SimEngine::new(cluster(2, 2), CostModel::default());
    let err = engine
        .fuzz_sweep(
            &[9, 3, 7],
            || {
                let mut plan = knn_plan(4, 2, 1)?;
                plan.initially_ready.clear();
                Ok(plan)
            },
            "withheld-frontier",
        )
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("minimal failing seed 3"),
        "error must name the minimal seed: {err}"
    );
    assert!(err.contains("with_fuzz_seed(3)"), "error must show the replay call: {err}");
}

// ---------------------------------------------------------------------------
// Placement equivalence: the sim fabric vs the live fabric.
// ---------------------------------------------------------------------------

fn rt(id: u64, inputs: Vec<(u64, Vec<NodeId>)>) -> ReadyTask {
    ReadyTask {
        id: TaskId(id),
        inputs,
        type_name: "t".into(),
    }
}

#[test]
fn sim_and_live_fabrics_route_identically_without_inflight_pressure() {
    // The equivalence property behind the simulator's fidelity claim: for
    // one push sequence, `RoutedReady` (sim) and `ShardedReady` (live,
    // with no transfer plane attached) produce the same shard verdicts
    // under every placement model.
    let pushes = |i: u64| -> ReadyTask {
        match i % 4 {
            0 => rt(i + 1, vec![]),
            1 => rt(i + 1, vec![(4096, vec![NodeId(1)])]),
            2 => rt(i + 1, vec![(512, vec![NodeId(0)]), (2048, vec![NodeId(2)])]),
            _ => rt(i + 1, vec![(128, vec![NodeId((i % 3) as u32)])]),
        }
    };
    for model in ["bytes", "cost", "roundrobin", "adaptive"] {
        let mut sim = RoutedReady::new("fifo", 3, placement_by_name(model).unwrap()).unwrap();
        let live = ShardedReady::new("fifo", 3, placement_by_name(model).unwrap(), None).unwrap();
        let sim_verdicts: Vec<usize> = (0..24).map(|i| sim.push(pushes(i))).collect();
        let live_verdicts: Vec<usize> = (0..24).map(|i| live.push(pushes(i))).collect();
        assert_eq!(
            sim_verdicts, live_verdicts,
            "model '{model}' diverged between sim and live fabrics"
        );
    }
}

#[test]
fn claim_time_charging_is_the_only_placement_divergence() {
    // The simulator charges transfers at *claim* time, so its fabric
    // always sees zero in-flight pressure — the one documented divergence
    // from a live `cost` run mid-transfer. Pin it: with a transfer toward
    // node 1 in flight, the live fabric credits node 1 and routes the
    // consumer there, while the sim fabric (same model, same pushes)
    // keeps chasing the resident replica's queue. Everything before the
    // pressure-carrying push stays identical.
    struct Toward1;
    impl InflightSource for Toward1 {
        fn inflight_toward(&self, node: NodeId) -> u64 {
            if node == NodeId(1) {
                1000
            } else {
                0
            }
        }
    }
    let mut sim = RoutedReady::new("fifo", 2, placement_by_name("cost").unwrap()).unwrap();
    let live = ShardedReady::new(
        "fifo",
        2,
        placement_by_name("cost").unwrap(),
        Some(Arc::new(Toward1)),
    )
    .unwrap();
    // Pressure-free warm-up push: both fabrics agree (shard 0).
    assert_eq!(sim.push(rt(1, vec![(8, vec![NodeId(0)])])), 0);
    assert_eq!(live.push(rt(1, vec![(8, vec![NodeId(0)])])), 0);
    // The consumer of a version mid-transfer toward node 1: live credits
    // the in-flight bytes (cost 0 on node 1), sim sees zero pressure and
    // stays with the replica on node 0 despite its queued task.
    let consumer = || rt(2, vec![(1000, vec![NodeId(0)])]);
    assert_eq!(sim.push(consumer()), 0, "sim charges transfers at claim time");
    assert_eq!(live.push(consumer()), 1, "live credits in-flight pressure");
}

// ---------------------------------------------------------------------------
// Live plane: the yield-point harness under a pinned seed.
// ---------------------------------------------------------------------------

fn tiny_shapes() -> Shapes {
    Shapes {
        km_frag_n: 96,
        km_d: 4,
        km_k: 3,
        ..Shapes::default()
    }
}

fn tiny_kmeans(rt_handle: &CompssRuntime) -> RValue {
    let mut cfg = KmeansConfig::small(11);
    cfg.shapes = tiny_shapes();
    cfg.fragments = 4;
    cfg.iterations = 3;
    kmeans::run_kmeans(rt_handle, &cfg, Backend::Native)
        .unwrap()
        .centroids
}

#[test]
fn fuzzed_transfer_failures_keep_board_accounting_and_results_exact() {
    // The PR-4 regression through the live yield-point plane: a 4-node
    // run under a pinned fuzz seed widens the mover/GC/purge hazard
    // windows while an injector fails the first transfer attempts, so
    // retries, tombstone purges, and GC collections interleave in the
    // perturbed order. The board identity `prefetched + waited + dropped
    // + failed == requested` and result correctness must survive any such
    // interleaving. Everything is pinned — router, injector, chaos — so
    // the ambient CI matrix env cannot perturb the schedule's meaning.
    let clean = {
        let rt_handle = CompssRuntime::start(
            RuntimeConfig::local(2)
                .with_nodes(4, 2)
                .with_router("cost")
                .with_chaos(ChaosSpec::default()),
        )
        .unwrap();
        let centroids = tiny_kmeans(&rt_handle);
        rt_handle.stop().unwrap();
        centroids
    };
    let mut config = RuntimeConfig::local(2)
        .with_nodes(4, 2)
        .with_router("cost")
        .with_transfer_threads(2)
        .with_sched_fuzz(7)
        .with_chaos(ChaosSpec::default());
    config.injector = Arc::new(FailureInjector::new(1.0, "__transfer__", 6, 42));
    let rt_handle = CompssRuntime::start(config).unwrap();
    let centroids = tiny_kmeans(&rt_handle);
    let stats = rt_handle.stop().unwrap();
    assert!(
        clean.all_equal(&centroids, 1e-9),
        "fuzzed schedule changed the result"
    );
    assert_eq!(stats.tasks_failed, 0, "{stats:?}");
    assert!(stats.transfers_failed >= 1, "the transfer injector never fired: {stats:?}");
    assert_eq!(
        stats.transfers_prefetched
            + stats.transfers_waited
            + stats.transfers_dropped
            + stats.transfers_failed,
        stats.transfers_requested,
        "transfer-board accounting identity broken: {stats:?}"
    );
    assert_eq!(stats.dead_version_bytes, 0, "{stats:?}");
    assert!(
        stats.sched_fuzz_perturbations > 0,
        "the armed yield points never fired: {stats:?}"
    );
}

#[test]
fn disarmed_plane_takes_zero_perturbations() {
    // The zero-overhead claim, observably: without a seed the controller
    // is never even constructed, so the visit count is exactly 0.
    let mut config = RuntimeConfig::local(2).with_nodes(2, 2).with_transfer_threads(1);
    config.sched_fuzz = None; // pin against an ambient RCOMPSS_SCHED_FUZZ
    let rt_handle = CompssRuntime::start(config).unwrap();
    let centroids = tiny_kmeans(&rt_handle);
    let stats = rt_handle.stop().unwrap();
    assert!(centroids.as_real().is_some());
    assert_eq!(stats.sched_fuzz_perturbations, 0, "{stats:?}");
}

#[test]
fn armed_plane_replays_one_deterministic_decision_stream_per_seed() {
    // Two runtimes under one seed see identical perturbation schedules at
    // every site (per-instance controllers, pure decision function); a
    // different seed sees a different schedule. The visit *counts* may
    // differ run to run (OS scheduling varies), so the contract is pinned
    // on the pure schedule, which the runtime consumes verbatim.
    use rcompss::coordinator::schedfuzz::{schedule, FuzzSite};
    for site in [
        FuzzSite::ReadyPush,
        FuzzSite::TransferComplete,
        FuzzSite::GcCollect,
        FuzzSite::NodeKill,
    ] {
        assert_eq!(schedule(7, site, 128), schedule(7, site, 128));
        assert_ne!(schedule(7, site, 128), schedule(8, site, 128));
    }
    // And a fuzzed runtime actually consumes that stream: the counter
    // proves the sites were visited.
    let rt_handle = CompssRuntime::start(
        RuntimeConfig::local(2)
            .with_nodes(2, 2)
            .with_transfer_threads(1)
            .with_sched_fuzz(7)
            .with_chaos(ChaosSpec::default()),
    )
    .unwrap();
    let add = rt_handle.register_task(TaskDef::new("add", 2, |a| {
        Ok(vec![RValue::scalar(
            a[0].as_f64().unwrap() + a[1].as_f64().unwrap(),
        )])
    }));
    let mut acc = rt_handle.submit(&add, &[1.0.into(), 1.0.into()]).unwrap();
    for _ in 0..16 {
        acc = rt_handle.submit(&add, &[acc.into(), 1.0.into()]).unwrap();
    }
    let v = rt_handle.wait_on(&acc).unwrap().as_f64().unwrap();
    let stats = rt_handle.stop().unwrap();
    assert_eq!(v, 18.0);
    assert!(stats.sched_fuzz_perturbations > 0, "{stats:?}");
}

// ---------------------------------------------------------------------------
// Fleet scale: the 1,000-node / 10^6-task capacity requirement.
// ---------------------------------------------------------------------------

#[test]
fn fleet_scale_sim_drains_a_wide_cluster() {
    // Always-on scaled-down guard (20k tasks over 1,000 nodes): the
    // interned per-node state and allocation-free event loop must drain a
    // fleet-wide plan promptly even in debug builds.
    let plan = fleet_plan(4_000, 5);
    let n = plan.graph.len();
    assert_eq!(n, 20_000);
    let report = SimEngine::new(cluster(1_000, 4), CostModel::default())
        .with_router("roundrobin")
        .with_fuzz_seed(1)
        .run(plan, "fleet-20k")
        .unwrap();
    assert_eq!(report.tasks_done, n);
    assert_eq!(report.dead_version_bytes, 0);
}

#[test]
#[ignore = "release-scale: ~1M tasks x multiple seeds; CI runs it with --include-ignored"]
fn fleet_scale_million_task_fuzz_sweep() {
    // The acceptance bar: a 1,000-node, 10^6-task synthetic plan sweeps
    // multiple fuzz seeds at single-digit seconds per seed (release).
    let engine = SimEngine::new(cluster(1_000, 4), CostModel::default())
        .with_router("roundrobin");
    let reports = engine
        .fuzz_sweep(&seeds(4, 2), || Ok(fleet_plan(20_000, 50)), "fleet-1m")
        .unwrap();
    assert_eq!(reports.len(), 2);
    for r in &reports {
        assert_eq!(r.tasks_done, 1_000_000);
        assert_eq!(r.dead_version_bytes, 0);
    }
}
