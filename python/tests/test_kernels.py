"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (tile-aligned, as the kernels require) and data
distributions; every kernel must match ``ref.py`` to float32 tolerance.
This is the core compute-correctness signal of the repo.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import distances, gram, matmul, ref

jax.config.update("jax_platform_name", "cpu")

COMMON = dict(deadline=None, max_examples=12,
              suppress_health_check=[hypothesis.HealthCheck.too_slow])


def rng_array(seed, *shape, scale=1.0):
    return (np.random.default_rng(seed)
            .normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# distances.pairwise_sq_dists
# ---------------------------------------------------------------------------

@settings(**COMMON)
@given(
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    d=st.sampled_from([8, 64, 96]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-2, 1.0, 1e2]),
)
def test_pairwise_sq_dists_matches_ref(mt, nt, d, seed, scale):
    m, n = mt * distances.TILE_M, nt * distances.TILE_N
    test = rng_array(seed, m, d, scale=scale)
    train = rng_array(seed + 1, n, d, scale=scale)
    got = distances.pairwise_sq_dists(test, train)
    want = ref.pairwise_sq_dists(jnp.asarray(test), jnp.asarray(train))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale**2)


def test_pairwise_sq_dists_zero_for_identical_points():
    x = rng_array(0, distances.TILE_M, 32)
    d = np.asarray(distances.pairwise_sq_dists(x, x))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)
    assert (d >= 0).all(), "squared distances must be non-negative"


def test_pairwise_rejects_misaligned_shapes():
    with pytest.raises(AssertionError):
        distances.pairwise_sq_dists(np.zeros((100, 8), np.float32),
                                    np.zeros((128, 8), np.float32))


# ---------------------------------------------------------------------------
# gram.ztz / gram.zty
# ---------------------------------------------------------------------------

@settings(**COMMON)
@given(
    rp=st.integers(1, 3),
    pp=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_ztz_matches_ref(rp, pp, seed):
    n, p = rp * gram.PANEL_R, pp * gram.TILE_P
    x = rng_array(seed, n, p)
    got = np.asarray(gram.ztz(x))
    want = np.asarray(ref.lr_ztz(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-3)
    # Gram matrices are symmetric PSD.
    np.testing.assert_allclose(got, got.T, rtol=1e-5, atol=1e-4)


@settings(**COMMON)
@given(
    rp=st.integers(1, 3),
    pp=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_zty_matches_ref(rp, pp, seed):
    n, p = rp * gram.PANEL_R, pp * gram.TILE_P
    x = rng_array(seed, n, p)
    y = rng_array(seed + 7, n)
    got = np.asarray(gram.zty(x, y))
    want = np.asarray(ref.lr_zty(jnp.asarray(x), jnp.asarray(y)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-3)


def test_ztz_accumulates_over_panels():
    # Two row-panels must equal the sum of their separate Grams.
    x = rng_array(3, 2 * gram.PANEL_R, gram.TILE_P)
    whole = np.asarray(gram.ztz(x))
    parts = (np.asarray(gram.ztz(x[:gram.PANEL_R]))
             + np.asarray(gram.ztz(x[gram.PANEL_R:])))
    np.testing.assert_allclose(whole, parts, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# matmul.matmul
# ---------------------------------------------------------------------------

@settings(**COMMON)
@given(
    mi=st.integers(1, 2),
    ni=st.integers(1, 2),
    ki=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(mi, ni, ki, seed):
    m, n, k = mi * matmul.TILE_M, ni * matmul.TILE_N, ki * matmul.TILE_K
    a = rng_array(seed, m, k)
    b = rng_array(seed + 1, k, n)
    got = np.asarray(matmul.matmul(a, b))
    want = np.asarray(ref.gemm(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=5e-3)


def test_matmul_identity():
    n = matmul.TILE_K
    a = rng_array(11, matmul.TILE_M, n)
    eye = np.eye(n, matmul.TILE_N, dtype=np.float32)
    got = np.asarray(matmul.matmul(a, eye))
    np.testing.assert_allclose(got[:, :min(n, matmul.TILE_N)],
                               a[:, :min(n, matmul.TILE_N)], rtol=1e-6)


def test_matmul_rejects_misaligned():
    with pytest.raises(AssertionError):
        matmul.matmul(np.zeros((64, 256), np.float32),
                      np.zeros((256, 128), np.float32))
