"""Layer-2 correctness: task bodies vs reference semantics + shape checks.

Each task body (the functions ``aot.py`` lowers) must (a) produce the
shapes declared in the manifest and (b) agree with the ``ref.py`` oracle
composition — e.g. running knn_frag + knn_merge over fragments must equal a
brute-force k-NN over the concatenated training set.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

S = model.SHAPES


def rng(seed):
    return np.random.default_rng(seed)


def test_task_table_shapes_agree_with_eval_shape():
    for name, (fn, args) in model.task_functions().items():
        outs = jax.eval_shape(fn, *args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        for o in outs:
            assert all(dim > 0 for dim in o.shape), f"{name}: bad shape {o.shape}"


def test_knn_frag_merge_equals_bruteforce():
    r = rng(0)
    tb, d, k = S["knn_test_block"], S["knn_d"], S["knn_k"]
    tn = S["knn_train_n"]
    test = r.normal(size=(tb, d)).astype(np.float32)
    frags_x = [r.normal(size=(tn, d)).astype(np.float32) for _ in range(3)]
    frags_y = [r.integers(0, S["knn_classes"], size=tn).astype(np.float32)
               for _ in range(3)]

    # Task-graph evaluation: frag + pairwise merges.
    parts = [model.knn_frag(jnp.asarray(test), jnp.asarray(x), jnp.asarray(y))
             for x, y in zip(frags_x, frags_y)]
    d01, l01 = model.knn_merge(parts[0][0], parts[0][1], parts[1][0], parts[1][1])
    dm, lm = model.knn_merge(d01, l01, parts[2][0], parts[2][1])

    # Brute force over the concatenated training set.
    all_x = jnp.asarray(np.concatenate(frags_x))
    all_y = jnp.asarray(np.concatenate(frags_y))
    dref, lref = ref.knn_frag(jnp.asarray(test), all_x, all_y, k)

    np.testing.assert_allclose(np.sort(np.asarray(dm), axis=1),
                               np.sort(np.asarray(dref), axis=1),
                               rtol=1e-3, atol=1e-2)
    # Final classification must agree.
    got = np.asarray(model.knn_classify(lm)[0])
    want = np.asarray(ref.knn_classify(lref.astype(jnp.int32), S["knn_classes"]))
    assert (got == want).mean() > 0.99


def test_kmeans_partial_matches_ref_and_merges():
    r = rng(1)
    n, d, k = S["km_frag_n"], S["km_d"], S["km_k"]
    pts = r.normal(size=(n, d)).astype(np.float32)
    cents = r.normal(size=(k, d)).astype(np.float32)
    sums, counts = model.kmeans_partial(jnp.asarray(pts), jnp.asarray(cents))
    rs, rc = ref.kmeans_partial(jnp.asarray(pts), jnp.asarray(cents))
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rs),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(rc))
    assert float(jnp.sum(counts)) == n

    # Splitting the fragment and merging partials must be equivalent.
    s1, c1 = model.kmeans_partial(jnp.asarray(np.vstack([pts[: n // 2],
                                                         pts[: n // 2]])),
                                  jnp.asarray(cents))
    assert float(jnp.sum(c1)) == n


def test_kmeans_update_handles_empty_clusters():
    k, d = S["km_k"], S["km_d"]
    sums = jnp.ones((k, d), jnp.float32)
    counts = jnp.zeros((k,), jnp.float32).at[0].set(2.0)
    old = jnp.full((k, d), 7.0, jnp.float32)
    new = np.asarray(model.kmeans_update(sums, counts, old)[0])
    np.testing.assert_allclose(new[0], 0.5)
    np.testing.assert_allclose(new[1:], 7.0)


def test_linreg_pipeline_recovers_beta():
    r = rng(2)
    n, p = S["lr_frag_n"], S["lr_p"]
    beta_true = r.normal(size=p).astype(np.float32) * 0.1
    frags = []
    for i in range(4):
        x = r.normal(size=(n, p)).astype(np.float32)
        y = (x @ beta_true + 0.001 * r.normal(size=n)).astype(np.float32)
        frags.append((x, y))

    ztz_total = None
    zty_total = None
    for x, y in frags:
        zz = model.lr_ztz(jnp.asarray(x))[0]
        zy = model.lr_zty(jnp.asarray(x), jnp.asarray(y))[0]
        ztz_total = zz if ztz_total is None else model.merge_add2(ztz_total, zz)[0]
        zty_total = zy if zty_total is None else model.merge_add2(zty_total, zy)[0]

    beta = np.asarray(model.lr_solve(ztz_total, zty_total)[0])
    np.testing.assert_allclose(beta, beta_true, rtol=5e-2, atol=5e-3)

    # Prediction: X @ beta via the Pallas matmul path.
    xp = frags[0][0][: S["lr_pred_block"]]
    pred = np.asarray(model.lr_predict(jnp.asarray(xp), jnp.asarray(beta))[0])
    np.testing.assert_allclose(pred, xp @ beta, rtol=1e-2, atol=2e-2)


def test_merge_add2_is_elementwise_sum():
    a = jnp.arange(12.0, dtype=jnp.float32).reshape(3, 4)
    got = np.asarray(model.merge_add2(a, a)[0])
    np.testing.assert_allclose(got, 2 * np.asarray(a))
