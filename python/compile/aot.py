"""AOT compile path: lower every Layer-2 task body to HLO text + manifest.

Usage (normally via ``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md). Lowering uses
``return_tuple=True`` so the Rust side always unwraps a tuple.

The manifest (``manifest.json``) records per-task input/output shapes and
dtypes so the Rust runtime can validate literals before execution.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import SHAPES, task_functions


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def out_specs(fn, example_args):
    outs = jax.eval_shape(fn, *example_args)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    return [spec_json(o) for o in outs]


def main() -> int:
    ap = argparse.ArgumentParser(description="RCOMPSs AOT artifact builder")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated task names (default: all)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    table = task_functions()
    selected = (
        {k: table[k] for k in args.only.split(",")} if args.only else table
    )

    manifest = {"shapes": SHAPES, "tasks": {}}
    for name, (fn, example_args) in sorted(selected.items()):
        hlo = to_hlo_text(fn, example_args)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(hlo)
        digest = hashlib.sha256(hlo.encode()).hexdigest()[:16]
        manifest["tasks"][name] = {
            "file": fname,
            "sha256_16": digest,
            "inputs": [spec_json(s) for s in example_args],
            "outputs": out_specs(fn, example_args),
        }
        print(f"  lowered {name:24s} -> {fname} ({len(hlo)/1024:.0f} KiB)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(manifest['tasks'])} artifacts + manifest.json "
          f"to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
