"""Pallas kernel: tiled pairwise squared-distance matrix (Layer 1).

The compute hot-spot shared by KNN_frag (distances test x train) and
K-means partial_sum (distances points x centroids). Written for the TPU
memory hierarchy — ``BlockSpec`` tiles stage (TM, d) and (TN, d) panels into
VMEM, the cross term is a single MXU matmul per tile, and the squared norms
are fused rank-1 updates — then executed here with ``interpret=True`` so the
lowered HLO runs on the CPU PJRT plugin (see DESIGN.md §Hardware-Adaptation).

VMEM footprint per grid step (TM=TN=128, d<=256, f32):
    2*128*256*4 B (panels) + 128*128*4 B (out tile) ~= 320 KiB  << 16 MiB.
Arithmetic intensity ~= 64 FLOP/B -> MXU compute-bound.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes aligned to the MXU systolic array (128x128).
TILE_M = 128
TILE_N = 128


def _sq_dist_kernel(test_ref, train_ref, o_ref):
    """One (TILE_M, TILE_N) output tile of squared distances."""
    a = test_ref[...]          # (TILE_M, d) panel in VMEM
    b = train_ref[...]         # (TILE_N, d) panel in VMEM
    # Cross term on the MXU; preferred_element_type keeps f32 accumulation.
    cross = jax.lax.dot_general(
        a, b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    a2 = jnp.sum(a * a, axis=1, keepdims=True)   # (TILE_M, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True).T  # (1, TILE_N)
    o_ref[...] = jnp.maximum(a2 + b2 - 2.0 * cross, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pairwise_sq_dists(test: jnp.ndarray, train: jnp.ndarray,
                      interpret: bool = True) -> jnp.ndarray:
    """(n_test, d) x (n_train, d) -> (n_test, n_train) squared distances.

    Requires n_test % TILE_M == 0 and n_train % TILE_N == 0 (the callers
    pick fragment shapes accordingly; ragged edges are padded at L2).
    """
    n_test, d = test.shape
    n_train, d2 = train.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    assert n_test % TILE_M == 0, f"n_test={n_test} not a multiple of {TILE_M}"
    assert n_train % TILE_N == 0, f"n_train={n_train} not a multiple of {TILE_N}"
    grid = (n_test // TILE_M, n_train // TILE_N)
    return pl.pallas_call(
        _sq_dist_kernel,
        grid=grid,
        in_specs=[
            # Row panel of test points: varies with i, full feature dim.
            pl.BlockSpec((TILE_M, d), lambda i, j: (i, 0)),
            # Row panel of train points: varies with j.
            pl.BlockSpec((TILE_N, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_test, n_train), jnp.float32),
        interpret=interpret,
    )(test.astype(jnp.float32), train.astype(jnp.float32))
