"""Pallas kernel: general tiled matmul (Layer 1).

Used by compute_prediction (X @ beta, with beta broadcast to a narrow
matrix) and as the calibration GEMM for the MKL-vs-RBLAS ratio the cluster
profiles need (DESIGN.md §3). Classic three-level tiling: (TM, TN) output
tiles, K swept in VMEM-resident panels via the innermost grid dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128
TILE_N = 128
TILE_K = 256


def _matmul_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(a: jnp.ndarray, b: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """(m, k) @ (k, n) with m % TILE_M == n % TILE_N == k % TILE_K == 0."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims differ: {k} vs {k2}"
    assert m % TILE_M == 0 and n % TILE_N == 0 and k % TILE_K == 0, (
        f"shape ({m},{k})x({k},{n}) not aligned to "
        f"({TILE_M},{TILE_K},{TILE_N}) tiles"
    )
    grid = (m // TILE_M, n // TILE_N, k // TILE_K)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, TILE_K), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE_K, TILE_N), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
