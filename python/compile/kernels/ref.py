"""Pure-jnp reference oracles for the Pallas kernels (Layer 1 correctness).

Every Pallas kernel in this package has a reference implementation here
written in straightforward jax.numpy. The pytest suite asserts
``assert_allclose(kernel(x), ref(x))`` over shape/dtype sweeps — this is the
core correctness signal for the compute layer, mirroring how the paper
validates its R task implementations against base-R equivalents.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sq_dists(test: jnp.ndarray, train: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distances, (n_test, d) x (n_train, d) -> (n_test, n_train).

    The KNN_frag task's hot spot (§4.1: "computes the distance to all
    training points").
    """
    # ||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b  (one GEMM + rank-1 updates)
    a2 = jnp.sum(test * test, axis=1, keepdims=True)
    b2 = jnp.sum(train * train, axis=1, keepdims=True).T
    cross = test @ train.T
    return jnp.maximum(a2 + b2 - 2.0 * cross, 0.0)


def knn_frag(test: jnp.ndarray, train_x: jnp.ndarray, train_y: jnp.ndarray,
             k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """KNN_frag: local k nearest neighbours of each test point within one
    training fragment. Returns (distances (n_test, k), labels (n_test, k))."""
    d = pairwise_sq_dists(test, train_x)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, train_y[idx]


def knn_merge(d1: jnp.ndarray, l1: jnp.ndarray, d2: jnp.ndarray,
              l2: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """KNN_merge: combine two (n_test, k) partial neighbour sets, keeping the
    k smallest distances (paper: merge tasks "progressively aggregate the
    distances and corresponding class labels")."""
    k = d1.shape[1]
    d = jnp.concatenate([d1, d2], axis=1)
    lab = jnp.concatenate([l1, l2], axis=1)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, jnp.take_along_axis(lab, idx, axis=1)


def knn_classify(labels: jnp.ndarray, n_classes: int) -> jnp.ndarray:
    """KNN_classify: majority vote over the global k neighbour labels."""
    votes = jax.nn.one_hot(labels.astype(jnp.int32), n_classes, dtype=jnp.float32)
    return jnp.argmax(jnp.sum(votes, axis=1), axis=1).astype(jnp.int32)


def kmeans_partial(points: jnp.ndarray, centroids: jnp.ndarray
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """partial_sum: assign each point of a fragment to its nearest centroid
    and return (per-cluster coordinate sums (k, d), per-cluster counts (k,)).
    """
    d = pairwise_sq_dists(points, centroids)
    labels = jnp.argmin(d, axis=1)
    onehot = jax.nn.one_hot(labels, centroids.shape[0], dtype=points.dtype)
    sums = onehot.T @ points
    counts = jnp.sum(onehot, axis=0)
    return sums, counts


def kmeans_update(sums: jnp.ndarray, counts: jnp.ndarray,
                  old: jnp.ndarray) -> jnp.ndarray:
    """Centroid update; empty clusters keep their previous position."""
    safe = jnp.maximum(counts, 1.0)[:, None]
    fresh = sums / safe
    return jnp.where(counts[:, None] > 0, fresh, old)


def lr_ztz(x: jnp.ndarray) -> jnp.ndarray:
    """partial_ztz: fragment contribution X^T X ((p, p))."""
    return x.T @ x


def lr_zty(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """partial_zty: fragment contribution X^T y ((p,))."""
    return x.T @ y


def lr_solve(ztz: jnp.ndarray, zty: jnp.ndarray) -> jnp.ndarray:
    """compute_model_parameters: solve (X^T X) beta = X^T y via Cholesky
    with a tiny ridge for numerical safety."""
    p = ztz.shape[0]
    a = ztz + 1e-6 * jnp.eye(p, dtype=ztz.dtype)
    c = jax.scipy.linalg.cho_factor(a)
    return jax.scipy.linalg.cho_solve(c, zty)


def lr_predict(x: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """compute_prediction: X @ beta."""
    return x @ beta


def gemm(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul — the calibration kernel for the MKL/RBLAS ratio."""
    return a @ b
