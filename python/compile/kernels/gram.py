"""Pallas kernel: blocked Gram-matrix update X^T X and X^T y (Layer 1).

Linear regression's partial_ztz / partial_zty tasks are GEMM-heavy (§4.3:
"four different tasks involve GEMM operations"). The canonical MXU pattern:
(TP, TP) output tiles of X^T X accumulated over row panels of X staged
through VMEM. The row-panel loop is the innermost grid dimension so the
output tile stays resident in VMEM across the accumulation (the revisiting
pattern Pallas guarantees for sequential grids).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_P = 128   # output tile side (feature blocks)
PANEL_R = 256  # row panel height


def _ztz_kernel(xi_ref, xj_ref, o_ref):
    """Accumulate one (TILE_P, TILE_P) tile of X^T X over row panels."""
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xi = xi_ref[...]   # (PANEL_R, TILE_P)
    xj = xj_ref[...]   # (PANEL_R, TILE_P)
    o_ref[...] += jax.lax.dot_general(
        xi, xj,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def ztz(x: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """X^T X for X of shape (n, p); n % PANEL_R == 0, p % TILE_P == 0."""
    n, p = x.shape
    assert n % PANEL_R == 0, f"n={n} not a multiple of {PANEL_R}"
    assert p % TILE_P == 0, f"p={p} not a multiple of {TILE_P}"
    grid = (p // TILE_P, p // TILE_P, n // PANEL_R)
    return pl.pallas_call(
        _ztz_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((PANEL_R, TILE_P), lambda i, j, r: (r, i)),
            pl.BlockSpec((PANEL_R, TILE_P), lambda i, j, r: (r, j)),
        ],
        # All r-steps hit the same output tile -> in-VMEM accumulation.
        out_specs=pl.BlockSpec((TILE_P, TILE_P), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, p), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), x.astype(jnp.float32))


def _zty_kernel(x_ref, y_ref, o_ref):
    """Accumulate one (TILE_P,) block of X^T y over row panels."""
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]          # (PANEL_R, TILE_P)
    y = y_ref[...]          # (PANEL_R, 1)
    o_ref[...] += jax.lax.dot_general(
        x, y,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def zty(x: jnp.ndarray, y: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """X^T y for X (n, p), y (n,). Returns (p,)."""
    n, p = x.shape
    assert y.shape == (n,)
    assert n % PANEL_R == 0 and p % TILE_P == 0
    grid = (p // TILE_P, n // PANEL_R)
    out = pl.pallas_call(
        _zty_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((PANEL_R, TILE_P), lambda i, r: (r, i)),
            pl.BlockSpec((PANEL_R, 1), lambda i, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_P, 1), lambda i, r: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, 1), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), y.astype(jnp.float32).reshape(n, 1))
    return out.reshape(p)
