"""Layer 1 — Pallas kernels for the benchmark apps' compute hot-spots.

``distances`` (KNN / K-means pairwise distances), ``gram`` (linear
regression X^T X / X^T y), ``matmul`` (prediction GEMM + calibration), and
``ref`` (the pure-jnp oracles the pytest suite checks everything against).

All kernels are lowered with ``interpret=True`` so the emitted HLO contains
no Mosaic custom-calls and runs on the CPU PJRT plugin the Rust runtime
loads (see /opt/xla-example/README.md).
"""

from . import distances, gram, matmul, ref  # noqa: F401
