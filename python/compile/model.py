"""Layer 2 — the benchmark apps' task bodies as jax functions.

Each function here is the compute of one RCOMPSs task type from §4 of the
paper (KNN_frag, KNN_merge, partial_sum, partial_ztz, ...), expressed in
jax and calling the Layer-1 Pallas kernels for the hot spots. ``aot.py``
lowers every entry of ``TASK_FUNCTIONS`` to an HLO-text artifact which the
Rust workers execute through PJRT — Python never runs at request time.

Shape policy: HLO is static-shaped, so each task type is lowered for the
canonical fragment shapes in ``SHAPES``. The Rust apps generate fragments
in exactly these shapes (padding ragged tails), mirroring how the paper's R
implementation fixes per-fragment block sizes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import distances, gram, matmul, ref

# ---------------------------------------------------------------------------
# Canonical fragment shapes (kept MXU-tile-aligned for the Pallas kernels).
# ---------------------------------------------------------------------------

SHAPES = {
    # KNN (§4.1): fixed training fragment, per-task test block, k neighbours.
    "knn_train_n": 2048,
    "knn_test_block": 512,
    "knn_d": 64,
    "knn_k": 8,
    "knn_classes": 10,
    # K-means (§4.2): per-task point fragment, k centroids.
    "km_frag_n": 4096,
    "km_d": 64,
    "km_k": 16,
    # Linear regression (§4.3): per-task row fragment, p features
    # (intercept column included in X).
    "lr_frag_n": 2048,
    "lr_p": 256,
    "lr_pred_block": 2048,
    # Calibration GEMM.
    "gemm_n": 512,
}

F32 = jnp.float32
I32 = jnp.int32


def _s(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), F32)


def _si(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), I32)


# ---------------------------------------------------------------------------
# KNN task bodies (Figure 3).
# ---------------------------------------------------------------------------

def _k_smallest(d, lab, k):
    """Co-sort (distances, labels) rows ascending and keep the first k.

    ``jax.lax.top_k`` lowers to a ``topk`` HLO instruction that the
    Rust-side XLA (0.5.1) cannot parse; ``lax.sort`` lowers to plain
    ``sort`` HLO which it can.
    """
    sd, sl = jax.lax.sort((d, lab), dimension=1, num_keys=1)
    return sd[:, :k], sl[:, :k]


def knn_frag(test, train_x, train_y):
    """KNN_frag: local k-NN of a test block within one training fragment.

    Distances come from the Pallas kernel; k-smallest selection stays in
    jnp (lowers to an HLO sort).
    """
    k = SHAPES["knn_k"]
    d = distances.pairwise_sq_dists(test, train_x)
    lab = jnp.broadcast_to(train_y.astype(I32)[None, :], d.shape)
    return _k_smallest(d, lab, k)


def knn_merge(d1, l1, d2, l2):
    """KNN_merge: keep the k nearest of two partial neighbour sets."""
    k = SHAPES["knn_k"]
    d = jnp.concatenate([d1, d2], axis=1)
    lab = jnp.concatenate([l1.astype(I32), l2.astype(I32)], axis=1)
    return _k_smallest(d, lab, k)


def knn_classify(labels):
    """KNN_classify: majority vote; returns int32 class per test point."""
    votes = jax.nn.one_hot(labels.astype(I32), SHAPES["knn_classes"], dtype=F32)
    return (jnp.argmax(jnp.sum(votes, axis=1), axis=1).astype(I32),)


# ---------------------------------------------------------------------------
# K-means task bodies (Figure 4).
# ---------------------------------------------------------------------------

def kmeans_partial(points, centroids):
    """partial_sum: nearest-centroid assignment + per-cluster sums/counts.

    The distance matrix is the Pallas kernel; the scatter-style reduction is
    a one-hot GEMM, which XLA fuses tightly. The k centroids are padded to a
    full MXU tile (distance columns beyond k are sliced off before argmin).
    """
    k = SHAPES["km_k"]
    pad_rows = distances.TILE_N - k
    far = jnp.full((pad_rows, centroids.shape[1]), 1e6, dtype=F32)
    padded = jnp.concatenate([centroids, far], axis=0)
    d = distances.pairwise_sq_dists(points, padded)[:, :k]
    labels = jnp.argmin(d, axis=1)
    onehot = jax.nn.one_hot(labels, SHAPES["km_k"], dtype=F32)
    sums = jax.lax.dot_general(
        onehot, points, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=F32)
    counts = jnp.sum(onehot, axis=0)
    return sums, counts


def kmeans_update(sums, counts, old):
    """Merge result -> new centroids; empty clusters keep old positions."""
    safe = jnp.maximum(counts, 1.0)[:, None]
    fresh = sums / safe
    return (jnp.where(counts[:, None] > 0, fresh, old),)


# ---------------------------------------------------------------------------
# Linear regression task bodies (Figure 5).
# ---------------------------------------------------------------------------

def lr_ztz(x):
    """partial_ztz via the blocked Gram Pallas kernel."""
    return (gram.ztz(x),)


def lr_zty(x, y):
    """partial_zty via the blocked Pallas kernel."""
    return (gram.zty(x, y),)


def lr_solve(ztz_, zty_):
    """compute_model_parameters: solve (X^T X + eps I) beta = X^T y.

    Conjugate gradients instead of LAPACK Cholesky: ``cho_factor`` lowers to
    a typed-FFI custom-call the Rust-side XLA (0.5.1) cannot execute, while
    CG is pure HLO (a While loop of matvecs) and the ridge-stabilized Gram
    matrix is SPD, where CG converges in <= p iterations.
    """
    p = SHAPES["lr_p"]
    a = ztz_ + 1e-6 * jnp.eye(p, dtype=F32)

    def body(_, state):
        x, r, pv, rs = state
        ap = a @ pv
        alpha = rs / (pv @ ap + 1e-30)
        x = x + alpha * pv
        r_new = r - alpha * ap
        rs_new = r_new @ r_new
        beta = rs_new / (rs + 1e-30)
        return (x, r_new, r_new + beta * pv, rs_new)

    x0 = jnp.zeros_like(zty_)
    state = (x0, zty_, zty_, zty_ @ zty_)
    x, *_ = jax.lax.fori_loop(0, p, body, state)
    return (x,)


def lr_predict(x, beta):
    """compute_prediction: X @ beta through the tiled matmul kernel
    (beta broadcast to a (p, TILE_N) panel, first column taken)."""
    n = SHAPES["lr_pred_block"]
    p = SHAPES["lr_p"]
    beta_panel = jnp.tile(beta[:, None], (1, matmul.TILE_N))
    out = matmul.matmul(x.reshape(n, p), beta_panel)
    return (out[:, 0],)


# ---------------------------------------------------------------------------
# Shared / calibration bodies.
# ---------------------------------------------------------------------------

def merge_add2(a, b):
    """Generic pairwise merge: elementwise sum (K-means & linreg merges)."""
    return (a + b,)


def gemm_cal(a, b):
    """Calibration GEMM for the MKL/RBLAS ratio (Pallas path)."""
    return (matmul.matmul(a, b),)


# ---------------------------------------------------------------------------
# AOT export table: name -> (fn, example_args).
# ---------------------------------------------------------------------------

def task_functions():
    s = SHAPES
    tb, tn, d = s["knn_test_block"], s["knn_train_n"], s["knn_d"]
    k = s["knn_k"]
    kn, kd, kk = s["km_frag_n"], s["km_d"], s["km_k"]
    ln, lp = s["lr_frag_n"], s["lr_p"]
    pn = s["lr_pred_block"]
    g = s["gemm_n"]
    return {
        "knn_frag": (knn_frag, (_s(tb, d), _s(tn, d), _s(tn))),
        "knn_merge": (knn_merge, (_s(tb, k), _si(tb, k), _s(tb, k), _si(tb, k))),
        "knn_classify": (knn_classify, (_si(tb, k),)),
        "kmeans_partial": (kmeans_partial, (_s(kn, kd), _s(kk, kd))),
        "kmeans_update": (kmeans_update, (_s(kk, kd), _s(kk), _s(kk, kd))),
        "lr_ztz": (lr_ztz, (_s(ln, lp),)),
        "lr_zty": (lr_zty, (_s(ln, lp), _s(ln))),
        "lr_solve": (lr_solve, (_s(lp, lp), _s(lp))),
        "lr_predict": (lr_predict, (_s(pn, lp), _s(lp))),
        "merge_add2_kmsums": (merge_add2, (_s(kk, kd), _s(kk, kd))),
        "merge_add2_kmcounts": (merge_add2, (_s(kk), _s(kk))),
        "merge_add2_ztz": (merge_add2, (_s(lp, lp), _s(lp, lp))),
        "merge_add2_zty": (merge_add2, (_s(lp), _s(lp))),
        "gemm_cal": (gemm_cal, (_s(g, g), _s(g, g))),
    }
